"""Distribution tests on 8 FAKE devices via subprocess (the main test
process must keep seeing 1 device — see launch/dryrun.py's contract).

Covers: sharded ACE sketch exactness (psum merge == bulk build), sharded
train-step lowering on a debug mesh, elastic checkpoint reshard, pipeline
parallelism vs sequential reference, and the dry-run entry itself on one
small cell.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# every test here round-trips a subprocess with a forced multi-device CPU
# topology — minutes, not seconds; the CI fast lane (-m "not slow") skips them
pytestmark = pytest.mark.slow


def run_py(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


class TestShardedSketch:
    def test_shardmap_update_matches_bulk(self):
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core import sketch as sk
            from repro.core.distributed import make_shardmap_update
            from repro.core.sketch import AceConfig

            cfg = AceConfig(dim=8, num_bits=6, num_tables=10, seed=0)
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            w = sk.make_params(cfg)
            x = jnp.asarray(
                np.random.default_rng(0).normal(size=(64, 8)), jnp.float32)
            upd = make_shardmap_update(mesh, cfg, data_axes=("data",))
            with jax.set_mesh(mesh):
                state = jax.device_put(
                    sk.init(cfg),
                    jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                 sk.init(cfg)))
                xs = jax.device_put(x, NamedSharding(mesh, P("data")))
                out = upd(state, xs, w)
            ref = sk.insert(sk.init(cfg), w, x, cfg)
            assert bool(jnp.all(out.counts == ref.counts)), "counts differ"
            assert abs(float(out.n) - float(ref.n)) < 1e-5
            print("SHARDED_OK", float(sk.mean_mu(out)),
                  float(sk.mean_mu(ref)))
        """)
        assert "SHARDED_OK" in out

    def test_spmd_train_step_on_debug_mesh(self):
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.models.registry import Arch
            from repro.models.common import set_rules
            from repro.train.train_loop import (TrainConfig,
                                                init_train_state,
                                                make_train_step)
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            set_rules({"batch": ("data",), "heads": "model",
                       "kv_heads": "model", "ff": "model",
                       "vocab": "model"})
            a = Arch("olmo_1b", reduced=True)
            tcfg = TrainConfig(use_data_filter=True, use_grad_monitor=True,
                               microbatches=2, warmup_steps=1,
                               peak_lr=1e-3)
            with jax.set_mesh(mesh):
                state = init_train_state(a, tcfg, jax.random.PRNGKey(0))
                step = jax.jit(make_train_step(a, tcfg))
                rng = np.random.default_rng(0)
                batch = {"tokens": jnp.asarray(
                             rng.integers(0, 512, (8, 16)), jnp.int32),
                         "labels": jnp.asarray(
                             rng.integers(0, 512, (8, 16)), jnp.int32)}
                batch = {k: jax.device_put(
                             v, NamedSharding(mesh, P("data")))
                         for k, v in batch.items()}
                losses = []
                for _ in range(4):
                    state, metrics = step(state, batch)
                    losses.append(float(metrics["loss"]))
            assert all(np.isfinite(l) for l in losses)
            assert losses[-1] < losses[0]   # lr warms up after step 0
            print("SPMD_TRAIN_OK", losses[0], losses[-1])
        """)
        assert "SPMD_TRAIN_OK" in out

    def test_elastic_checkpoint_reshard(self, tmp_path):
        # save on a 1x1 layout (here), restore onto 4x2 in the subprocess
        import jax, jax.numpy as jnp
        from repro.train import checkpoint as ck
        tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
        ck.save(str(tmp_path), 3, tree)
        out = run_py(f"""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.train import checkpoint as ck
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            like = {{"w": jnp.zeros((8, 4), jnp.float32)}}
            sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
            tree, man = ck.restore({str(tmp_path)!r}, 3, like, sh)
            assert tree["w"].sharding == sh["w"]
            np.testing.assert_array_equal(
                np.asarray(tree["w"]),
                np.arange(32, dtype=np.float32).reshape(8, 4))
            print("RESHARD_OK", man["step"])
        """)
        assert "RESHARD_OK" in out


class TestPipelineParallel:
    def test_gpipe_matches_sequential(self):
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.dist.pipeline import pipeline_apply, bubble_fraction
            S, M, mb, D = 4, 8, 2, 16
            mesh = jax.make_mesh((S,), ("pipe",))
            rng = np.random.default_rng(0)
            params = {"w": jnp.asarray(rng.normal(size=(S, D, D)) * 0.3,
                                       jnp.float32)}
            x = jnp.asarray(rng.normal(size=(M, mb, D)), jnp.float32)

            def layer_fn(p, h):
                return jnp.tanh(h @ p["w"])

            out = pipeline_apply(layer_fn, params, x, mesh=mesh,
                                 num_stages=S, num_microbatches=M)
            # sequential reference: apply the 4 stages in order
            ref = x
            for s in range(S):
                ref = jnp.tanh(ref @ params["w"][s])
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)
            assert abs(bubble_fraction(4, 8) - 3 / 11) < 1e-9
            print("PIPE_OK")
        """, devices=4)
        assert "PIPE_OK" in out


class TestDryrunEntry:
    def test_dryrun_small_cell_both_meshes(self, tmp_path):
        """The dry-run module itself, on the cheapest cell, both meshes.
        (The full 40-cell sweep artifacts live in dryrun_results/.)"""
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "whisper_tiny", "--shape", "train_4k",
             "--both-meshes", "--out", str(tmp_path)],
            capture_output=True, text=True, timeout=900, env=env,
            cwd=REPO)
        assert out.returncode == 0, out.stderr[-2000:]
        for mesh in ("16x16", "2x16x16"):
            with open(tmp_path / f"whisper_tiny__train_4k__{mesh}.json") as f:
                res = json.load(f)
            assert res["ok"], res["error"]
            assert res["collectives"]["total_bytes"] > 0
