"""Perf-regression gate tests: the gate must catch real regressions and
must NEVER flake on container timing noise alone.

Drives ``scripts/bench_gate.py`` (loaded by file path — scripts/ is not
a package) through synthetic BENCH JSON fixtures shaped like the real
committed ones: nested section dicts, ``rep_*`` spread lists, config
echo keys that must be ignored.
"""
from __future__ import annotations

import importlib.util
import json
import pathlib

import pytest

_SCRIPT = (pathlib.Path(__file__).resolve().parent.parent
           / "scripts" / "bench_gate.py")


def _load_gate():
    spec = importlib.util.spec_from_file_location("bench_gate", _SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


gate = _load_gate()

# Shaped like the committed BENCH_fleet.json: section dicts, a rep list
# whose spread (18.67/60.25 = 0.31) documents real container noise.
BASE_FLEET = {
    "num_tenants": 64, "batch": 64, "num_bits": 10,
    "legacy_loop": {"items_per_s": 850.0, "median_step_ms": 75.0},
    "fleet_scan": {"items_per_s": 51000.0, "median_chunk_ms": 20.0,
                   "trace_count": 1},
    "speedup_scan": 60.25,
    "rep_speedups_scan": [18.67, 60.25, 77.41],
}


def _write(dirpath, name, payload):
    dirpath.mkdir(parents=True, exist_ok=True)
    (dirpath / name).write_text(json.dumps(payload))


def _run(tmp_path, base, fresh, **kw):
    bdir, fdir = tmp_path / "base", tmp_path / "fresh"
    for name, payload in base.items():
        _write(bdir, name, payload)
    for name, payload in fresh.items():
        _write(fdir, name, payload)
    report = tmp_path / "report.json"
    rc = gate.main(["--baseline-dir", str(bdir), "--fresh-dir", str(fdir),
                    "--report", str(report)]
                   + [str(a) for a in kw.pop("extra", [])])
    return rc, json.loads(report.read_text())


class TestGateVerdicts:
    def test_true_regression_fails(self, tmp_path):
        fresh = {"legacy_loop": {"items_per_s": 840.0},
                 "fleet_scan": {"items_per_s": 900.0},   # 57x drop
                 "speedup_scan": 1.1,
                 "rep_speedups_scan": [1.1]}
        rc, report = _run(tmp_path,
                          {"BENCH_fleet.json": BASE_FLEET},
                          {"BENCH_fleet.json": fresh})
        assert rc == 1 and not report["ok"]
        failed = {f["metric"] for f in report["failures"]}
        assert "fleet_scan.items_per_s" in failed
        assert "speedup_scan" in failed
        # the stable metric passed — failures are per-metric, not per-file
        assert any(p["metric"] == "legacy_loop.items_per_s"
                   for p in report["passes"])

    def test_container_noise_alone_passes(self, tmp_path):
        # Fresh run lands at the BOTTOM of the baseline's own observed
        # rep spread (18.67 of median 60.25).  The adaptive floor
        # (0.31 * 0.8 = 0.248) must absorb it — this exact shape is what
        # a naive 0.9x gate would flake on weekly.
        fresh = {"legacy_loop": {"items_per_s": 850.0},
                 "fleet_scan": {"items_per_s": 16000.0},
                 "speedup_scan": 18.8,
                 "rep_speedups_scan": [18.8]}
        rc, report = _run(tmp_path,
                          {"BENCH_fleet.json": BASE_FLEET},
                          {"BENCH_fleet.json": fresh})
        assert rc == 0 and report["ok"], report["failures"]
        floor = report["passes"][0]["floor_ratio"]
        assert floor < 0.5   # spread-derived, tighter than fail_ratio

    def test_stable_bench_gets_tight_floor(self, tmp_path):
        # No rep_* list in the baseline -> no noise evidence -> the gate
        # uses fail_ratio itself, and a 2.5x drop fails.
        base = {"fleet_scan": {"items_per_s": 50000.0}}
        fresh = {"fleet_scan": {"items_per_s": 20000.0}}
        rc, report = _run(tmp_path,
                          {"BENCH_fleet.json": base},
                          {"BENCH_fleet.json": fresh})
        assert rc == 1
        assert report["failures"][0]["floor_ratio"] == pytest.approx(0.5)

    def test_missing_metric_fails(self, tmp_path):
        fresh = dict(BASE_FLEET)
        del fresh["speedup_scan"]          # silently-dropped benchmark
        rc, report = _run(tmp_path,
                          {"BENCH_fleet.json": BASE_FLEET},
                          {"BENCH_fleet.json": fresh})
        assert rc == 1
        assert any(f["metric"] == "speedup_scan"
                   and "missing" in f["reason"]
                   for f in report["failures"])

    def test_missing_fresh_file_fails(self, tmp_path):
        rc, report = _run(tmp_path,
                          {"BENCH_fleet.json": BASE_FLEET,
                           "BENCH_window.json": {"speedup": 5.0}},
                          {"BENCH_fleet.json": BASE_FLEET})
        assert rc == 1
        assert any(f["bench"] == "window" and f["metric"] is None
                   for f in report["failures"])

    def test_new_benchmark_is_note_not_failure(self, tmp_path):
        rc, report = _run(tmp_path,
                          {"BENCH_fleet.json": BASE_FLEET},
                          {"BENCH_fleet.json": BASE_FLEET,
                           "BENCH_shiny.json": {"items_per_s": 1.0}})
        assert rc == 0 and report["ok"]
        assert [n["bench"] for n in report["notes"]] == ["shiny"]

    def test_best_of_reps_absorbs_one_bad_run(self, tmp_path):
        # Two fresh reps of the same bench: one descheduled, one fine.
        # Best-of-reps must pass.
        good = {"fleet_scan": {"items_per_s": 52000.0}, "speedup_scan": 61.0,
                "legacy_loop": {"items_per_s": 850.0},
                "rep_speedups_scan": [61.0]}
        bad = {"fleet_scan": {"items_per_s": 400.0}, "speedup_scan": 0.5,
               "legacy_loop": {"items_per_s": 850.0},
               "rep_speedups_scan": [0.5]}
        rc, report = _run(tmp_path,
                          {"BENCH_fleet.json": BASE_FLEET},
                          {"BENCH_fleet.json": bad,
                           "BENCH_fleet.rep2.json": good})
        assert rc == 0, report["failures"]


class TestGateMechanics:
    def test_config_echo_keys_not_gated(self):
        leaves = gate._flatten(BASE_FLEET)
        gated = sorted(p for p in leaves if gate._GATED.search(p))
        assert gated == ["fleet_scan.items_per_s",
                         "legacy_loop.items_per_s", "speedup_scan"]
        # ms latencies, trace counts, config echo: all ignored
        assert "num_tenants" in leaves
        assert not gate._GATED.search("fleet_scan.median_chunk_ms")
        assert not gate._GATED.search("fleet_scan.trace_count")

    def test_eff_bw_metrics_are_gated(self):
        assert gate._GATED.search("eff_bw_win")
        assert gate._GATED.search("dtype_sweep.eff_bw_ratio_int8")
        assert gate._GATED.search("speedup_step")

    def test_rep_list_value_is_median(self):
        assert gate._value([18.67, 77.41, 60.25]) == 60.25
        assert gate._value(42.0) == 42.0

    def test_spread_ratio_from_rep_lists(self):
        leaves = gate._flatten(BASE_FLEET)
        assert gate._spread_ratio(leaves) == pytest.approx(
            18.67 / 60.25, rel=1e-6)
        assert gate._spread_ratio({"items_per_s": 5.0}) == 1.0

    def test_bench_name_parsing(self):
        assert gate._bench_name("BENCH_fleet.json") == "fleet"
        assert gate._bench_name("/a/b/BENCH_fleet.rep2.json") == "fleet"

    def test_empty_dirs_exit_2(self, tmp_path):
        (tmp_path / "e1").mkdir()
        (tmp_path / "e2").mkdir()
        rc = gate.main(["--baseline-dir", str(tmp_path / "e1"),
                        "--fresh-dir", str(tmp_path / "e2")])
        assert rc == 2
