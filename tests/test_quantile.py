"""Property suite for the quantile threshold stack (repro.quantile).

The admission quantile is a log-binned additive rate histogram, so its
correctness story is algebraic, not statistical: merge is EXACT addition
(a commutative/associative monoid on the integer-valued f32 histograms
the streams build), the windowed view is the same γ^age epoch combine as
every other ring statistic, and the inverse-CDF read-out is within one
bin of the exact empirical quantile on ANY input ordering or shape —
including the adversarial ones (sorted, constant, heavy-tailed,
sub-RATE_MIN underflow) where streaming quantile structures classically
degrade.  Each of those claims is asserted here against brute-force
numpy oracles rebuilt from the raw rate draws, plus the E=1 contract
that a single-epoch windowed quantile filter is bitwise the flat one.

Strategies draw sizes/seeds/kind selectors as integers and derive the
actual rate streams from a seeded ``np.random.default_rng`` — the same
idiom as tests/test_sketch_properties.py, and the subset of hypothesis
the hermetic-container shim in conftest.py supports.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

jax.config.update("jax_platform_name", "cpu")

from repro.core.sketch import AceConfig            # noqa: E402
from repro.quantile import sketch as qsk           # noqa: E402
from repro.quantile.moments import falpha_index    # noqa: E402
from repro.quantile.sketch import (                # noqa: E402
    NUM_BINS, RATE_MIN, _RATIO, bin_edges, bin_index, hist_quantile,
    init_hist, merge_hists, observe_rates, observe_rates_fleet,
    quantile_threshold)
from repro.window import ring                      # noqa: E402

_EDGES = np.asarray(bin_edges())


def _rates(rng: np.random.Generator, n: int, kind: int) -> np.ndarray:
    """Adversarial rate streams, all float32 in [0, 1.2]."""
    if kind == 0:                                   # uniform
        r = rng.uniform(0.0, 1.0, n)
    elif kind == 1:                                 # constant (all ties)
        r = np.full(n, rng.uniform(0.0, 1.0))
    elif kind == 2:                                 # pre-sorted
        r = np.sort(rng.uniform(0.0, 1.0, n))
    elif kind == 3:                                 # heavy-tailed Pareto
        r = np.minimum(rng.pareto(1.1, n) * 1e-3, 1.2)
    else:                                           # lognormal spanning
        r = np.minimum(rng.lognormal(-8.0, 4.0, n), 1.2)  # the underflow bin
    return r.astype(np.float32)


def _np_hist(rates: np.ndarray) -> np.ndarray:
    """Oracle histogram: scatter the module's own bin ids with np.add.at
    (tests the masked-scatter/ring mechanics, not the binning float)."""
    h = np.zeros(NUM_BINS, np.float32)
    np.add.at(h, np.asarray(bin_index(jnp.asarray(rates))), 1.0)
    return h


def _np_bin(x: float) -> int:
    """Edge-based oracle bin of a raw value."""
    return int(np.clip(np.searchsorted(_EDGES, x, side="right") - 1,
                       0, NUM_BINS - 1))


class TestMergeMonoid:
    """merge = exact addition on unit-weight f32 histograms."""

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), na=st.integers(1, 200),
           nb=st.integers(1, 200), nc=st.integers(1, 200),
           kind=st.integers(0, 4))
    def test_merge_commutative_associative_bitwise(self, seed, na, nb,
                                                   nc, kind):
        rng = np.random.default_rng(seed)
        hs = [observe_rates(init_hist(), jnp.asarray(_rates(rng, n, kind)),
                            jnp.ones(n, jnp.float32))
              for n in (na, nb, nc)]
        a, b, c = hs
        assert np.array_equal(merge_hists(a, b), merge_hists(b, a))
        assert np.array_equal(merge_hists(merge_hists(a, b), c),
                              merge_hists(a, merge_hists(b, c)))
        # insertion-order invariance: one stream == merge of its splits
        rng = np.random.default_rng(seed)
        allr = np.concatenate([_rates(rng, n, kind) for n in (na, nb, nc)])
        whole = observe_rates(init_hist(), jnp.asarray(allr),
                              jnp.ones(allr.size, jnp.float32))
        assert np.array_equal(whole,
                              merge_hists(merge_hists(a, b), c))

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 300),
           kind=st.integers(0, 4))
    def test_masked_scatter_equals_dense_subset(self, seed, n, kind):
        rng = np.random.default_rng(seed)
        r = _rates(rng, n, kind)
        mask = (rng.uniform(size=n) < 0.6).astype(np.float32)
        fixed = observe_rates(init_hist(), jnp.asarray(r),
                              jnp.asarray(mask))
        sub = r[mask > 0]
        dense = observe_rates(init_hist(), jnp.asarray(sub),
                              jnp.ones(sub.size, jnp.float32))
        assert np.array_equal(fixed, dense)
        assert float(jnp.sum(fixed)) == float(mask.sum())

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 300),
           T=st.integers(1, 5))
    def test_fleet_scatter_equals_per_tenant_flat(self, seed, n, T):
        rng = np.random.default_rng(seed)
        r = _rates(rng, n, 0)
        tids = rng.integers(0, T, n).astype(np.int32)
        mask = (rng.uniform(size=n) < 0.8).astype(np.float32)
        fleet = observe_rates_fleet(init_hist(T), jnp.asarray(r),
                                    jnp.asarray(tids), jnp.asarray(mask))
        for t in range(T):
            sel = tids == t
            flat = observe_rates(init_hist(), jnp.asarray(r[sel]),
                                 jnp.asarray(mask[sel]))
            assert np.array_equal(np.asarray(fleet)[t], flat)


class TestWindowedCombine:
    """rotate-then-merge ≡ the γ^age-weighted windowed combine."""

    def _cfg(self):
        return AceConfig(dim=6, num_bits=5, num_tables=4, seed=3)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), E=st.integers(2, 4),
           n_batches=st.integers(2, 8), gi=st.integers(50, 100))
    def test_rotate_then_merge_equals_windowed_combine(self, seed, E,
                                                       n_batches, gi):
        gamma = gi / 100.0
        rng = np.random.default_rng(seed)
        state = ring.init(self._cfg(), E, quantile=True)
        ref = [np.zeros(NUM_BINS, np.float32) for _ in range(E)]
        cursor = 0
        for _ in range(n_batches):
            B = int(rng.integers(4, 32))
            r = _rates(rng, B, int(rng.integers(0, 5)))
            mask = (rng.uniform(size=B) < 0.9).astype(np.float32)
            state = ring.observe_current(state, jnp.asarray(r),
                                         jnp.asarray(mask))
            h = np.zeros(NUM_BINS, np.float32)
            np.add.at(h, np.asarray(bin_index(jnp.asarray(r))), mask)
            ref[cursor] += h
            if rng.integers(0, 2):                  # rotate half the time
                state = ring.rotate(state, gamma)
                cursor = (cursor + 1) % E
                ref[cursor] = np.zeros(NUM_BINS, np.float32)
        expect = sum(gamma ** ((cursor - e) % E) * ref[e]
                     for e in range(E))
        got = np.asarray(ring.combined_qhist(state, gamma))
        if gamma == 1.0:                            # unit weights: exact
            assert np.array_equal(got, expect.astype(np.float32))
        else:
            np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-5)
        # per-epoch rows themselves are exact regardless of γ (decay is
        # query-time weighting; rotation only ever zeroes a row)
        perm = [(cursor - a) % E for a in range(E)]    # rows by age
        assert np.array_equal(np.asarray(state.qhist)[perm],
                              np.stack([ref[e] for e in perm]))

    def test_full_ring_of_rotations_returns_to_zero(self):
        state = ring.init(self._cfg(), 3, quantile=True)
        r = jnp.asarray(np.linspace(0.0, 0.9, 16, dtype=np.float32))
        state = ring.observe_current(state, r, jnp.ones(16, jnp.float32))
        for _ in range(3):
            state = ring.rotate(state, 0.7)
        assert np.array_equal(np.asarray(state.qhist),
                              np.zeros((3, NUM_BINS), np.float32))


class TestQuantileAccuracy:
    """Inverse-CDF read-out is within one log bin of the exact empirical
    quantile on every adversarial stream shape."""

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(20, 400),
           qi=st.integers(1, 99), kind=st.integers(0, 4))
    def test_one_bin_rank_bracket_vs_exact(self, seed, n, qi, kind):
        q = qi / 100.0
        rng = np.random.default_rng(seed)
        r = _rates(rng, n, kind)
        hist = observe_rates(init_hist(), jnp.asarray(r),
                             jnp.ones(n, jnp.float32))
        v = float(hist_quantile(hist, q))
        exact = float(np.quantile(r, q, method="inverted_cdf"))
        # the estimate's bin and the exact quantile's bin differ by ≤ 1
        # (equal up to the f32 rounding of the rank target q·N)
        iv, ie = _np_bin(v), _np_bin(exact)
        assert abs(iv - ie) <= 1, (v, exact, iv, ie)
        # value form of the same bound: within two geometric bin ratios
        # when both live on the geometric ladder [RATE_MIN, 1]
        if RATE_MIN <= exact <= 1.0 and v >= RATE_MIN:
            ratio = v / exact
            assert _RATIO ** -2 * 0.999 <= ratio <= _RATIO ** 2 * 1.001
        elif exact < RATE_MIN:                      # underflow bin
            assert v <= _EDGES[2]                   # ≤ one bin above it

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(20, 300),
           kind=st.integers(0, 4))
    def test_quantile_monotone_in_q(self, seed, n, kind):
        rng = np.random.default_rng(seed)
        hist = observe_rates(init_hist(),
                             jnp.asarray(_rates(rng, n, kind)),
                             jnp.ones(n, jnp.float32))
        qs = [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99]
        vals = [float(hist_quantile(hist, q)) for q in qs]
        assert all(a <= b + 1e-12 for a, b in zip(vals, vals[1:]))

    def test_empty_hist_is_zero_and_threshold_warmup_gates(self):
        assert float(hist_quantile(init_hist(), 0.5)) == 0.0
        hist = observe_rates(init_hist(),
                             jnp.asarray([0.1, 0.2, 0.3], jnp.float32),
                             jnp.ones(3, jnp.float32))
        assert np.isneginf(float(quantile_threshold(
            hist, jnp.float32(3.0), 0.5, warmup_items=10.0)))
        t = float(quantile_threshold(hist, jnp.float32(3.0), 0.5,
                                     warmup_items=2.0))
        assert t == pytest.approx(float(hist_quantile(hist, 0.5)) * 3.0)


class TestE1GuardrailEqualsFlat:
    """A single-epoch windowed quantile filter is BITWISE the flat
    quantile filter — same keeps, same margins, same histogram."""

    def test_e1_windowed_quantile_filter_bitwise_flat(self):
        from repro.data.pipeline import AceDataFilter
        from repro.window.filter import WindowedAceFilter
        kw = dict(d_model=16, num_bits=6, num_tables=8, alpha=3.0,
                  warmup_items=32.0, threshold_mode="quantile",
                  quantile_q=0.05)
        flat = AceDataFilter(**kw)
        wind = WindowedAceFilter(**kw, num_epochs=1, decay=1.0)
        fs, w = flat.init()
        ws, w2 = wind.init()
        assert np.array_equal(np.asarray(w), np.asarray(w2))
        rng = np.random.default_rng(11)
        for _ in range(6):
            emb = jnp.asarray(rng.normal(size=(16, 4, 16)), jnp.float32)
            feat = flat.features(emb)
            fs, fkeep, fmargin = flat.step(fs, w, feat)
            ws, wkeep, wmargin = wind.step(ws, w, feat)
            assert np.array_equal(np.asarray(fkeep), np.asarray(wkeep))
            assert np.array_equal(np.asarray(fmargin),
                                  np.asarray(wmargin))
        assert np.array_equal(np.asarray(fs.qhist),
                              np.asarray(ws.qhist)[0])
        # every finite row observes EXCEPT the cold-start steps: the
        # half-warmup calib_mask floor (16 items here) skips step 1
        assert float(jnp.sum(fs.qhist)) == 5 * 16


class TestFalphaIndex:
    """Normalized α-th frequency-moment drift index (repro.quantile
    .moments): 1 on uniform planes, maximal on point masses, stationary
    in stream volume."""

    def test_uniform_plane_is_one(self):
        counts = jnp.full((4, 32), 5, jnp.int32)       # n/m = 5 each
        out = falpha_index(counts, jnp.float32(5 * 32), alpha=1.25)
        assert float(out) == pytest.approx(1.0, rel=1e-5)

    def test_point_mass_is_m_to_alpha_minus_one(self):
        m, alpha = 32, 1.25
        counts = jnp.zeros((2, m), jnp.int32).at[:, 0].set(64)
        out = falpha_index(counts, jnp.float32(64), alpha=alpha)
        assert float(out) == pytest.approx(m ** (alpha - 1.0), rel=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), scale=st.integers(2, 16))
    def test_stationary_under_volume_scaling(self, seed, scale):
        rng = np.random.default_rng(seed)
        base = rng.integers(0, 20, size=(3, 64)).astype(np.int64)
        n = float(base[0].sum())
        a = falpha_index(jnp.asarray(base), jnp.float32(n))
        b = falpha_index(jnp.asarray(base * scale),
                         jnp.float32(n * scale))
        np.testing.assert_allclose(float(a), float(b), rtol=1e-4)

    def test_table_mask_restricts_mean(self):
        counts = jnp.stack([jnp.full((16,), 4, jnp.int32),
                            jnp.zeros((16,), jnp.int32).at[0].set(64)])
        mask = jnp.asarray([1.0, 0.0], jnp.float32)
        out = falpha_index(counts, jnp.float32(64), alpha=1.25,
                           table_mask=mask)
        assert float(out) == pytest.approx(1.0, rel=1e-5)
