"""repro.cluster unit tests — single-process, fake clocks, MemStore.

Covers the whole control plane without spawning processes: rendezvous
sharding (determinism, partition, minimal movement), heartbeat /
failure detection / rejoin backoff, gossip framing + the layered
integrity gates (CRC at transport, health_check at semantics), node
failover (gossip adoption, checkpoint fallback incl. torn-newest,
cold start, rejoin), the open-loop front end's shedding contracts,
and the satellite regressions: numeric checkpoint-step ordering,
autotuner persistent cache, and the quantized fleet merge oracles.

The two-REAL-process properties (KV over jax.distributed, the chaos
host-kill/re-shard acceptance test) live in
tests/test_cluster_multiprocess.py.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (ClusterConfig, ClusterNode, FailureDetector,
                           GossipBus, HeartbeatWriter, MemStore,
                           MembershipConfig, RejoinPolicy, ShardMap,
                           SnapshotCorrupt, pack_snapshot,
                           rendezvous_owner, snapshot_healthy,
                           unpack_snapshot, with_host, without_host)
from repro.core import sketch as sk
from repro.core.sketch import AceState
from repro.fleet import state as fl
from repro.fleet.filter import FleetDataFilter
from repro.resilience import inject
from repro.train import checkpoint as ckpt


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------

class TestShardMap:
    HOSTS = ("h0", "h1", "h2", "h3")

    def test_partition_and_determinism(self):
        m = ShardMap(version=0, hosts=self.HOSTS, num_tenants=64)
        owned = [m.owned_by(h) for h in self.HOSTS]
        flat = sorted(t for o in owned for t in o)
        assert flat == list(range(64))          # exact partition
        m2 = ShardMap(version=5, hosts=self.HOSTS, num_tenants=64)
        assert [m2.owned_by(h) for h in self.HOSTS] == owned
        # rendezvous_owner agrees with the map
        for t in range(64):
            assert m.owner_of(t) == rendezvous_owner(t, self.HOSTS)

    def test_rough_balance(self):
        m = ShardMap(version=0, hosts=self.HOSTS, num_tenants=256)
        sizes = [len(m.owned_by(h)) for h in self.HOSTS]
        assert min(sizes) >= 256 // len(self.HOSTS) // 3

    def test_minimal_movement_on_death(self):
        m = ShardMap(version=0, hosts=self.HOSTS, num_tenants=64)
        dead = "h2"
        m2 = without_host(m, dead)
        assert m2.version == 1 and dead not in m2.hosts
        for t in range(64):
            if m.owner_of(t) != dead:
                # survivors' tenants never move
                assert m2.owner_of(t) == m.owner_of(t)
            else:
                assert m2.owner_of(t) != dead

    def test_minimal_movement_on_join(self):
        small = ShardMap(version=0, hosts=("h0", "h1"), num_tenants=64)
        grown = with_host(small, "h2")
        assert grown.version == 1
        for t in range(64):
            if grown.owner_of(t) != "h2":
                # only the joiner's winnings move
                assert grown.owner_of(t) == small.owner_of(t)

    def test_rejoin_restores_original_split(self):
        m = ShardMap(version=0, hosts=self.HOSTS, num_tenants=64)
        back = with_host(without_host(m, "h1"), "h1")
        for t in range(64):
            assert back.owner_of(t) == m.owner_of(t)

    def test_tenant_mask(self):
        m = ShardMap(version=0, hosts=("h0", "h1"), num_tenants=16)
        masks = np.stack([m.tenant_mask(h) for h in m.hosts])
        assert masks.dtype == np.float32
        assert np.array_equal(masks.sum(axis=0), np.ones(16))
        for h in m.hosts:
            assert set(np.nonzero(m.tenant_mask(h))[0]) == \
                set(m.owned_by(h))

    def test_json_roundtrip(self):
        m = ShardMap(version=7, hosts=self.HOSTS, num_tenants=64)
        m2 = ShardMap.from_json(m.to_json())
        assert m2 == m

    def test_duplicate_hosts_rejected(self):
        with pytest.raises(ValueError):
            ShardMap(version=0, hosts=("h0", "h0"), num_tenants=4)


# ---------------------------------------------------------------------------
# membership
# ---------------------------------------------------------------------------

class TestMembership:
    def _pair(self, interval=0.2, timeout=1.0):
        clock = FakeClock()
        store = MemStore()
        cfg = MembershipConfig(heartbeat_interval=interval,
                               failure_timeout=timeout)
        return clock, store, cfg

    def test_config_validation(self):
        with pytest.raises(ValueError):
            MembershipConfig(heartbeat_interval=1.0, failure_timeout=0.5)

    def test_maybe_beat_rate_limits(self):
        clock, store, cfg = self._pair()
        hb = HeartbeatWriter(store, "h0", cfg, clock)
        assert hb.maybe_beat()
        assert not hb.maybe_beat()          # same instant: rate-limited
        clock.advance(0.25)
        assert hb.maybe_beat()
        assert store.get("hb/h0") == "2:0"   # seq:map_version stamp

    def test_detector_death_and_grace(self):
        clock, store, cfg = self._pair()
        hb = HeartbeatWriter(store, "h1", cfg, clock)
        det = FailureDetector(store, cfg, clock)
        hb.beat()
        assert det.poll(["h1"]) == []
        clock.advance(0.9)
        assert det.poll(["h1"]) == []       # inside timeout
        clock.advance(0.2)
        assert det.poll(["h1"]) == ["h1"]   # silence > timeout ⇒ dead
        hb.beat()                           # value changes ⇒ alive again
        assert det.poll(["h1"]) == []
        # a host never seen at all gets a grace window, not instant death
        assert det.poll(["ghost"]) == []
        clock.advance(1.1)
        assert det.poll(["ghost"]) == ["ghost"]

    def test_detector_forget_restarts_grace(self):
        clock, store, cfg = self._pair()
        hb = HeartbeatWriter(store, "h1", cfg, clock)
        det = FailureDetector(store, cfg, clock)
        hb.beat()
        assert det.poll(["h1"]) == []       # first observation
        clock.advance(1.1)
        assert det.poll(["h1"]) == ["h1"]
        det.forget("h1")
        assert det.poll(["h1"]) == []       # stale value, fresh window

    def test_rejoin_policy_bounded_backoff(self):
        pol = RejoinPolicy(max_attempts=4, base_delay=0.1, max_delay=0.5)
        delays = [pol.next_delay() for _ in range(5)]
        assert delays == [0.1, 0.2, 0.4, 0.5, None]
        pol.reset()
        assert pol.next_delay() == 0.1

    def test_stale_version_beats_do_not_reset_liveness(self):
        """S4 (heartbeat fence): a zombie revived with an OLD shard map
        keeps bumping fresh sequence numbers, but those value changes
        must not count as liveness until it catches up to the current
        map version — otherwise a rewound host blocks its own
        replacement forever."""
        clock, store, cfg = self._pair()
        hb = HeartbeatWriter(store, "h1", cfg, clock)
        det = FailureDetector(store, cfg, clock)
        hb.version = 3
        hb.beat()
        assert det.poll(["h1"]) == []          # first observation
        clock.advance(0.5)
        hb.beat()
        assert det.poll(["h1"]) == []          # genuine change
        # zombie rewind: fresh process state, old map regime
        zombie = HeartbeatWriter(store, "h1", cfg, clock)
        zombie.version = 1
        died = None
        for i in range(4):
            clock.advance(0.4)
            zombie.beat()                      # value churns every poll
            if det.poll(["h1"]) == ["h1"]:
                died = i
                break
        assert died is not None                # churn never reset the clock
        # catching up to the current regime re-arms liveness
        zombie.version = 3
        clock.advance(0.4)
        zombie.beat()
        assert det.poll(["h1"]) == []

    def test_legacy_bare_seq_heartbeats_still_parse(self):
        """Pre-fencing heartbeat values (bare sequence numbers) read as
        version 0 — mixed-version clusters keep detecting liveness."""
        clock, store, cfg = self._pair()
        det = FailureDetector(store, cfg, clock)
        store.set("hb/h1", "1")
        assert det.poll(["h1"]) == []
        clock.advance(0.5)
        store.set("hb/h1", "2")
        assert det.poll(["h1"]) == []
        clock.advance(1.1)
        assert det.poll(["h1"]) == ["h1"]


# ---------------------------------------------------------------------------
# gossip
# ---------------------------------------------------------------------------

def _small_filter(count_dtype="int32", num_tenants=4, insert_all=True):
    return FleetDataFilter(d_model=6, num_tenants=num_tenants, num_bits=5,
                           num_tables=4, warmup_items=16.0,
                           insert_all=insert_all, count_dtype=count_dtype)


def _feed(filt, state, w, tenants, n_batches, seed, B=16):
    """Feed each tenant ``n_batches`` single-tenant batches (dense,
    deterministic by (seed, tenant, index))."""
    for t in tenants:
        for i in range(n_batches):
            rng = np.random.default_rng(seed + 7919 * t + i)
            x = rng.normal(size=(B, 1, filt.d_model)).astype(np.float32)
            feat = filt.features(jnp.asarray(x))
            state, _, _ = filt.step(state, w,
                                    feat, jnp.full((B,), t, jnp.int32))
    return state


def _tenant(state, t, dtype=jnp.int32):
    return AceState(counts=jnp.asarray(state.counts[t]).astype(dtype),
                    n=jnp.asarray(state.n[t]),
                    welford_mean=jnp.asarray(state.welford_mean[t]),
                    welford_m2=jnp.asarray(state.welford_m2[t]))


class TestGossip:
    def _state(self, count_dtype="int32"):
        filt = _small_filter(count_dtype)
        state, w = filt.init()
        state = _feed(filt, state, w, range(4), 2, seed=0)
        return jax.device_get(state)

    def test_pack_unpack_roundtrip_bitwise(self):
        host = self._state()
        blob = pack_snapshot(host, [1, 3], epoch=5, map_version=7)
        epoch, states, ver = unpack_snapshot(blob)
        assert epoch == 5 and ver == 7 and set(states) == {1, 3}
        for t in (1, 3):
            assert np.array_equal(states[t].counts, host.counts[t])
            assert states[t].n == np.float32(host.n[t])
            assert states[t].welford_mean == np.float32(
                host.welford_mean[t])
            assert states[t].welford_m2 == np.float32(host.welford_m2[t])

    def test_narrow_dtype_preserved(self):
        host = self._state("int8")
        _, states, _ = unpack_snapshot(pack_snapshot(host, [0], epoch=1))
        assert states[0].counts.dtype == np.int8

    def test_truncated_blob_rejected(self):
        blob = pack_snapshot(self._state(), [0, 1], epoch=1)
        with pytest.raises(SnapshotCorrupt):
            unpack_snapshot(blob[:-40])

    def test_flipped_byte_rejected_by_crc(self):
        blob = bytearray(pack_snapshot(self._state(), [0, 1], epoch=1))
        # flip one payload byte mid-blob; framing may still parse, the
        # CRC must catch it
        blob[len(blob) // 2] ^= 0xFF
        with pytest.raises(SnapshotCorrupt):
            unpack_snapshot(bytes(blob))

    def test_preserialization_bitflip_passes_crc_fails_health(self):
        """Satellite 3: a sketch corrupted BEFORE serialization has
        valid CRCs — only the semantic gate can refuse it."""
        host = self._state()
        good = _tenant(host, 0)
        assert snapshot_healthy(good)
        bad = good._replace(counts=inject.flip_count_bits(
            good.counts, jax.random.PRNGKey(0), num_flips=4))
        blob = pack_snapshot(
            jax.device_get(fl.set_tenant(jnp_fleet(host), 0, bad)),
            [0], epoch=2)
        _, states, _ = unpack_snapshot(blob)    # CRC passes: no error
        assert not snapshot_healthy(states[0])  # health gate refuses

    def test_bus_publish_fetch_and_retention(self):
        store = MemStore()
        bus = GossipBus(store, "h0", keep=2)
        host = self._state()
        for e in range(1, 5):
            bus.publish(e, host, [0, 1])
        assert bus.published_epochs == 4 and bus.published_bytes > 0
        got = bus.latest("h0")
        assert got is not None and got[0] == 4
        # only `keep` epochs stay resident
        blobs = [k for k in store.keys("gossip/h0/")
                 if not k.endswith(("latest", "fence"))]
        assert sorted(blobs) == ["gossip/h0/3", "gossip/h0/4"]

    def test_bus_corrupt_newest_falls_back(self):
        store = MemStore()
        bus = GossipBus(store, "h0", keep=2)
        host = self._state()
        bus.publish(1, host, [0])
        bus.publish(2, host, [0, 1])
        store.set_bytes("gossip/h0/2",
                        b"garbage" + os.urandom(64))
        epoch, states, _ = bus.latest("h0")
        assert epoch == 1 and set(states) == {0}

    def test_bus_unknown_host(self):
        assert GossipBus(MemStore(), "h0").latest("nobody") is None

    def test_stale_version_publish_fenced(self):
        """S4: a revived host holding an OLD shard map cannot overwrite
        newer snapshots — its publish is a counted no-op, and a fresh
        bus instance (the revived process) still sees the fence because
        the high-water mark lives in the STORE."""
        store = MemStore()
        host = self._state()
        bus = GossipBus(store, "h0", keep=4)
        bus.publish(1, host, [0], map_version=2)
        bus.publish(2, host, [0, 1], map_version=2)
        zombie = GossipBus(store, "h0", keep=4)   # revived process
        assert zombie.publish(3, host, [0], map_version=1) == 0
        assert zombie.stale_publishes == 1
        assert zombie.published_epochs == 0
        got = bus.latest("h0")
        assert got is not None
        assert got[0] == 2 and got[2] == 2        # pointer never regressed

    def test_epoch_regression_same_version_fenced(self):
        """A rewound epoch counter under the SAME map version (restored
        backup) must not regress the latest pointer either."""
        store = MemStore()
        host = self._state()
        bus = GossipBus(store, "h0", keep=4)
        bus.publish(3, host, [0, 1], map_version=1)
        zombie = GossipBus(store, "h0", keep=4)
        assert zombie.publish(2, host, [0], map_version=1) == 0
        assert zombie.publish(3, host, [0], map_version=1) == 0
        assert zombie.stale_publishes == 2
        # a genuinely newer epoch still publishes
        assert zombie.publish(4, host, [0, 1], map_version=1) > 0
        assert bus.latest("h0")[0] == 4

    def test_raced_stale_blob_skipped_by_latest(self):
        """Even a stale blob RACED into the store (write interleaving
        the fence check) is refused at read time: ``latest`` skips any
        blob stamped below the host's fenced map version."""
        store = MemStore()
        host = self._state()
        bus = GossipBus(store, "h0", keep=4)
        bus.publish(1, host, [0], map_version=1)
        bus.publish(2, host, [0, 1], map_version=3)
        # zombie raced its blob in and flipped the pointer directly
        store.set_bytes("gossip/h0/3",
                        pack_snapshot(host, [1], epoch=3, map_version=1))
        store.set("gossip/h0/latest", "3")
        got = bus.latest("h0")
        assert got is not None
        assert got[0] == 2 and got[2] == 3        # fenced-intact blob wins


def jnp_fleet(host_state):
    return jax.tree.map(jnp.asarray, host_state)


# ---------------------------------------------------------------------------
# quantized fleet merge (satellite 3)
# ---------------------------------------------------------------------------

class TestQuantizedFleetMerge:
    @pytest.mark.parametrize("dtype", ["int8", "int16", "int32"])
    def test_merge_promote_commutes_bitwise(self, dtype):
        filt = _small_filter(dtype)
        state0, w = filt.init()
        a = _feed(filt, state0, w, range(4), 2, seed=10)
        b = _feed(filt, state0, w, range(4), 3, seed=20)
        m1 = fl.promote_fleet(fl.merge_fleet(a, b))
        m2 = fl.merge_fleet(fl.promote_fleet(a), fl.promote_fleet(b))
        for x, y in zip(jax.tree.leaves(m1), jax.tree.leaves(m2)):
            assert x.dtype == y.dtype
            assert np.array_equal(np.asarray(x), np.asarray(y))

    @pytest.mark.parametrize("dtype", ["int8", "int16"])
    def test_merge_matches_per_tenant_sketch_merge(self, dtype):
        filt = _small_filter(dtype)
        state0, w = filt.init()
        a = _feed(filt, state0, w, range(4), 2, seed=10)
        b = _feed(filt, state0, w, range(4), 3, seed=20)
        m = fl.merge_fleet(a, b)
        assert m.counts.dtype == jnp.int32
        for t in range(4):
            ref = sk.merge(_tenant(a, t), _tenant(b, t))
            assert np.array_equal(np.asarray(m.counts[t]),
                                  np.asarray(ref.counts))
            assert float(m.n[t]) == float(ref.n)
            assert float(m.welford_mean[t]) == float(ref.welford_mean)
            assert float(m.welford_m2[t]) == float(ref.welford_m2)

    def test_merge_equals_union_stream_counts(self):
        """insert_all streams: merged counts/n must EXACTLY equal the
        fleet that absorbed both streams (scatter-adds commute)."""
        filt = _small_filter("int16")
        state0, w = filt.init()
        a = _feed(filt, state0, w, range(4), 2, seed=10)
        b = _feed(filt, state0, w, range(4), 3, seed=20)
        both = _feed(_small_filter("int16"), state0, w, range(4), 2,
                     seed=10)
        both = _feed(filt, both, w, range(4), 3, seed=20)
        m = fl.merge_fleet(a, b)
        assert np.array_equal(np.asarray(m.counts),
                              np.asarray(both.counts).astype(np.int32))
        assert np.array_equal(np.asarray(m.n), np.asarray(both.n))
        # moments are NOT compared: the Welford stream tracks scores,
        # and stream b's scores differ when a's items are already in
        # the sketch — only counts/n are stream-order invariants

    def test_merge_shape_mismatch_rejected(self):
        a, _ = _small_filter("int8").init()
        b, _ = _small_filter("int8", num_tenants=2).init()
        with pytest.raises(ValueError):
            fl.merge_fleet(a, b)

    def test_merged_passes_health_check(self):
        filt = _small_filter("int8")
        state0, w = filt.init()
        a = _feed(filt, state0, w, range(4), 2, seed=10)
        b = _feed(filt, state0, w, range(4), 3, seed=20)
        m = jax.device_get(fl.merge_fleet(a, b))
        for t in range(4):
            assert snapshot_healthy(_tenant(jnp_fleet(m), t))


# ---------------------------------------------------------------------------
# node failover (MemStore + fake clock)
# ---------------------------------------------------------------------------

def _cluster_cfg(host, tmp_path=None, **kw):
    base = dict(
        host_id=host, hosts=("h0", "h1"), num_tenants=8, d_model=6,
        num_bits=5, num_tables=4, warmup_items=16.0, insert_all=True,
        chunk_T=4, epoch_chunks=2,
        ckpt_root=str(tmp_path) if tmp_path is not None else None,
        membership=MembershipConfig(heartbeat_interval=0.2,
                                    failure_timeout=1.0))
    base.update(kw)
    return ClusterConfig(**base)


def _chunk_for(node, seed):
    """One (chunk_T, B, d+1) chunk of single-tenant-dense batches over
    the node's owned tenants."""
    owned = node.owned()
    B, d = 8, node.cfg.d_model
    embeds, tids = [], []
    for j in range(node.cfg.chunk_T):
        t = owned[j % len(owned)]
        rng = np.random.default_rng(seed * 1000 + t)
        embeds.append(rng.normal(size=(B, 1, d)).astype(np.float32))
        tids.append(np.full((B,), t, np.int32))
    feats = node.filt.features(jnp.asarray(np.concatenate(embeds)))
    feats = feats.reshape(node.cfg.chunk_T, B, d + 1)
    return feats, np.stack(tids)


def _run_epochs(node, n_epochs, seed0=0):
    for i in range(n_epochs * node.cfg.epoch_chunks):
        node.ingest_chunk(*_chunk_for(node, seed0 + i))


class TestNodeFailover:
    def _two_nodes(self, tmp_path, **kw):
        store = MemStore()
        clock = FakeClock()
        n0 = ClusterNode(_cluster_cfg("h0", tmp_path, **kw), store, clock)
        n1 = ClusterNode(_cluster_cfg("h1", tmp_path, **kw), store, clock)
        return store, clock, n0, n1

    def _kill_and_detect(self, clock, n0):
        """Advance past the failure timeout (h1 silent) and run control
        steps until h0 owns everything."""
        clock.advance(0.5)
        n0.control_step()      # observes h1's last value
        clock.advance(1.2)
        dead = n0.control_step()
        assert dead == ["h1"]
        assert len(n0.owned()) == n0.cfg.num_tenants
        return dead

    def test_gossip_adoption_exact_n(self, tmp_path):
        store, clock, n0, n1 = self._two_nodes(tmp_path)
        _run_epochs(n0, 2)
        _run_epochs(n1, 2)
        h1_state = jax.device_get(n1.state)
        self._kill_and_detect(clock, n0)
        adopted = {a["tenant"]: a for a in n0.adoptions}
        assert set(adopted) == set(
            ShardMap(0, ("h0", "h1"), 8).owned_by("h1"))
        host0 = jax.device_get(n0.state)
        for t, rec in adopted.items():
            assert rec["source"] == "gossip"
            assert rec["source_epoch"] == 2
            assert float(host0.n[t]) == float(h1_state.n[t])
            assert np.array_equal(host0.counts[t], h1_state.counts[t])
        # misrouted accounting: requests for adopted tenants now serve
        _, keeps = n0.ingest_chunk(*_chunk_for(n0, 99))
        assert keeps.shape == (n0.cfg.chunk_T, 8)

    def test_checkpoint_fallback_with_torn_newest(self, tmp_path):
        """Gossip gone + newest checkpoint torn ⇒ adoption restores
        from the newest INTACT checkpoint (PR 7's CRC path)."""
        store, clock, n0, n1 = self._two_nodes(tmp_path)
        _run_epochs(n1, 3)     # checkpoints at epochs 1, 2, 3
        for k in list(store.keys("gossip/h1/")):
            store.delete(k)
        inject.tear_checkpoint(os.path.join(str(tmp_path), "h1"), 3)
        self._kill_and_detect(clock, n0)
        for rec in n0.adoptions:
            assert rec["source"] == "checkpoint"
            assert rec["source_epoch"] == 2    # newest INTACT
            assert rec["n"] > 0

    def test_unhealthy_gossip_rejected_before_merge(self, tmp_path):
        """Satellite 3: a bit-flipped (pre-serialization) gossiped
        sketch passes CRC but is refused by health_check — adoption
        falls back to the checkpoint."""
        store, clock, n0, n1 = self._two_nodes(tmp_path)
        _run_epochs(n1, 2)
        bad_counts = np.array(jax.device_get(n1.state).counts)
        for t in n1.owned():        # corrupt EVERY owned tenant's row
            bad_counts[t] = np.asarray(inject.flip_count_bits(
                jnp.asarray(bad_counts[t]), jax.random.PRNGKey(t),
                num_flips=2))
        bad = jax.device_get(n1.state)._replace(counts=bad_counts)
        n1.gossip.publish(3, bad, n1.owned())   # poisoned publish
        self._kill_and_detect(clock, n0)
        assert n0.adoptions
        for rec in n0.adoptions:
            assert rec["source"] == "checkpoint"

    def test_cold_start_when_no_candidates(self, tmp_path):
        store, clock, n0, n1 = self._two_nodes(None)   # no ckpt_root
        self._kill_and_detect(clock, n0)               # before any epoch
        assert n0.adoptions
        for rec in n0.adoptions:
            assert rec["source"] == "cold" and rec["n"] == 0.0
        # degraded but serving: the adopted tenants still take traffic
        n0.ingest_chunk(*_chunk_for(n0, 5))

    def test_rejoin_with_backoff(self, tmp_path):
        store, clock, n0, n1 = self._two_nodes(tmp_path)
        _run_epochs(n0, 1)
        _run_epochs(n1, 1)
        self._kill_and_detect(clock, n0)
        # fresh process, same identity, rejoining
        n1b = ClusterNode(_cluster_cfg("h1", tmp_path), store, clock)
        sleeps = []

        def sleep(d):
            sleeps.append(d)
            n0.control_step()      # coordinator runs while we wait

        assert n1b.try_rejoin(RejoinPolicy(max_attempts=3,
                                           base_delay=0.1), sleep)
        assert sleeps and sleeps[0] == 0.1
        assert n1b.map.version == n0.map.version
        assert set(n0.owned()) | set(n1b.owned()) == set(range(8))
        assert not (set(n0.owned()) & set(n1b.owned()))
        # rejoiner adopted its won-back tenants from the survivor
        assert {a["tenant"] for a in n1b.adoptions} == set(n1b.owned())

    def test_rejoin_budget_exhausted(self):
        store = MemStore()
        clock = FakeClock()
        n1 = ClusterNode(_cluster_cfg("h1"), store, clock)
        n1.map = without_host(n1.map, "h1")   # declared dead, nobody admits
        assert not n1.try_rejoin(RejoinPolicy(max_attempts=2),
                                 sleep=lambda d: None)

    def test_adoption_prefers_newer_map_version_over_larger_n(
            self, tmp_path):
        """S4: the shard-map version outranks stream volume in adoption
        preference.  A zombie-timeline checkpoint that absorbed MORE
        stream but was stamped under an older map regime must lose to
        newer-regime gossip — n is not a fencing token (a divergent
        zombie can inflate it), the map version is."""
        store, clock, n0, n1 = self._two_nodes(tmp_path)
        _run_epochs(n1, 1)
        early = jax.device_get(n1.state)        # less stream, real line
        _run_epochs(n1, 2, seed0=50)            # zombie keeps ingesting
        zombie = jax.device_get(n1.state)
        ckpt.save(n0._ckpt_dir("h1"), 99, n1.state,
                  extra={"map_version": 0}, keep=8)
        # the real timeline republished the early state under map v2
        GossipBus(store, "h1").publish(9, early, n1.owned(),
                                       map_version=2)
        self._kill_and_detect(clock, n0)
        assert n0.adoptions
        host0 = jax.device_get(n0.state)
        for rec in n0.adoptions:
            assert rec["source"] == "gossip"
            t = rec["tenant"]
            assert float(zombie.n[t]) > float(early.n[t])  # real conflict
            assert float(host0.n[t]) == float(early.n[t])

    def test_revived_stale_host_not_adopted_from(self, tmp_path):
        """S4 end-to-end: a zombie h1 (rewound epoch counter, old map)
        republishing after the regime moved on neither regresses the
        pointer nor pollutes what survivors adopt."""
        store, clock, n0, n1 = self._two_nodes(tmp_path)
        _run_epochs(n1, 2)
        live = jax.device_get(n1.state)
        GossipBus(store, "h1").publish(5, live, n1.owned(),
                                       map_version=3)
        zbus = GossipBus(store, "h1")           # revived process
        empty = jax.device_get(fl.init(n1.filt.fleet_cfg))
        assert zbus.publish(1, empty, n1.owned(), map_version=0) == 0
        assert zbus.stale_publishes == 1
        got = n0.gossip.latest("h1")
        assert got[0] == 5 and got[2] == 3
        for t in n1.owned():
            assert float(got[1][t].n) == float(live.n[t])

    def test_dead_coordinator_replaced(self, tmp_path):
        """h0 (the coordinator) dies: h1 must publish the successor map
        itself — the lowest LIVE host acts, not the configured one."""
        store, clock, n0, n1 = self._two_nodes(tmp_path)
        _run_epochs(n0, 1)
        clock.advance(0.5)
        n1.control_step()
        clock.advance(1.2)
        dead = n1.control_step()
        assert dead == ["h0"]
        assert n1.coordinator
        assert len(n1.owned()) == n1.cfg.num_tenants


# ---------------------------------------------------------------------------
# open-loop front end
# ---------------------------------------------------------------------------

class TestFrontEnd:
    def _mk(self, clock, policies=("fail_open", "fail_closed"), **kw):
        from repro.serve.engine import Guardrail, GuardrailConfig
        from repro.serve.frontend import FrontEnd, FrontEndConfig
        gcfg = GuardrailConfig(d_model=6, num_bits=5, num_tables=4,
                               warmup_items=16.0,
                               num_tenants=len(policies),
                               fail_policy=policies)
        g = Guardrail(gcfg)
        fcfg = FrontEndConfig(batch_size=4, seq=2, d_model=6, **kw)
        return g, FrontEnd(g, fcfg, clock=clock)

    def _embed(self, seed=0):
        return np.random.default_rng(seed).normal(
            size=(2, 6)).astype(np.float32)

    def test_full_batches_serve_all(self):
        clock = FakeClock()
        _, fe = self._mk(clock)
        tickets = [fe.submit(self._embed(i), tenant=i % 2)
                   for i in range(8)]
        while fe.ready():
            fe.pump()
        assert all(t.status == "served" for t in tickets)
        assert fe.metrics()["served"] == 8
        assert fe.metrics()["shed_rate"] == 0.0

    def test_queue_is_bounded_and_sheds_by_policy(self):
        clock = FakeClock()
        _, fe = self._mk(clock, max_queue=6)
        tickets = [fe.submit(self._embed(i), tenant=i % 2)
                   for i in range(20)]
        assert fe.queue_len == 6                 # bounded, never more
        shed = [t for t in tickets if t.status == "shed"]
        assert len(shed) == 14
        assert all(t.reason == "queue_full" for t in shed)
        for t in shed:   # fail_open tenant 0 ⇒ admit, fail_closed ⇒ reject
            assert t.admitted is (t.tenant == 0)
        fe.drain()
        assert fe.served == 6
        assert fe.metrics()["shed_queue_full"] == 14

    def test_submit_deadline_is_absolute(self):
        """Regression: ``submit(deadline=...)`` is ABSOLUTE on the
        front-end clock (the documented Ticket.deadline contract).  The
        old code treated the argument as relative slack — a deadline
        already in the past came out as a comfortable future one,
        deferring shedding by exactly the caller's submit lag (the
        coordinated-omission failure mode the open-loop bench anchors
        deadlines to scheduled arrivals to avoid)."""
        clock = FakeClock(t=100.0)
        _, fe = self._mk(clock)
        t = fe.submit(self._embed(), tenant=0, deadline=100.5)
        assert t.deadline == 100.5           # stored verbatim, not now+x
        # None still derives submit-time + default slack
        d = fe.submit(self._embed(2), tenant=0)
        assert d.deadline == clock.t + fe.cfg.default_deadline
        fe.pump(force=True)                  # arms the service estimate
        assert t.status == "served"
        # a deadline already in the past stays in the past — and sheds
        past = fe.submit(self._embed(1), tenant=1, deadline=99.0)
        assert past.deadline == 99.0 < clock.t
        fe.pump(force=True)
        assert past.status == "shed" and past.reason == "deadline"

    def test_deadline_shed_before_serving(self):
        clock = FakeClock()
        _, fe = self._mk(clock)
        # seed the service-time estimate with one served batch
        for i in range(4):
            fe.submit(self._embed(i), tenant=0)
        fe.pump()
        est = fe.est_service
        late = fe.submit(self._embed(9), tenant=1,
                         deadline=clock.t + 0.001)
        ok = fe.submit(self._embed(10), tenant=0,
                       deadline=clock.t + 60.0)
        clock.advance(0.002 + est)               # late is now hopeless
        fe.pump(force=True)
        assert late.status == "shed" and late.reason == "deadline"
        assert late.admitted is False            # fail_closed tenant
        assert ok.status == "served"
        assert fe.metrics()["shed_deadline"] == 1

    def test_cold_start_never_sheds_by_deadline(self):
        """S2: with ZERO measured service samples the deadline shed
        path must not fire — not even for requests already past their
        deadline (the first pump is also the jit trace, so tickets
        routinely age out while the executable builds).  The first real
        measurement arms the shed path."""
        clock = FakeClock()
        _, fe = self._mk(clock)
        t = fe.submit(self._embed(), tenant=1, deadline=clock.t + 0.001)
        clock.advance(10.0)               # way past deadline, 0 samples
        assert fe.est_service == 0.0      # placeholder, not a sample
        assert fe.pump(force=True) == 1   # served, NOT shed
        assert t.status == "served"
        assert fe.metrics()["shed_deadline"] == 0
        # one sample now exists: the shed path is armed
        late = fe.submit(self._embed(1), tenant=0,
                         deadline=clock.t + 0.001)
        clock.advance(1.0)
        fe.pump(force=True)
        assert late.status == "shed" and late.reason == "deadline"
        assert fe.metrics()["shed_deadline"] == 1

    def test_partial_batch_after_max_wait(self):
        clock = FakeClock()
        _, fe = self._mk(clock, max_wait=0.005)
        t = fe.submit(self._embed(), tenant=0, deadline=clock.t + 60.0)
        assert not fe.ready()
        clock.advance(0.006)
        assert fe.ready()
        assert fe.pump() == 1
        assert t.status == "served"

    def test_pad_rows_match_guardrail_quarantine(self):
        clock = FakeClock()
        g, fe = self._mk(clock)
        for i in range(5):                       # 1 full + 1 partial batch
            fe.submit(self._embed(i), tenant=0, deadline=clock.t + 60.0)
        fe.drain()
        assert fe.pad_rows == 3
        assert int(g.quarantined) == fe.pad_rows  # pads, nothing else

    def test_latency_accounting(self):
        clock = FakeClock()
        _, fe = self._mk(clock)
        t = fe.submit(self._embed(), tenant=0, deadline=clock.t + 60.0)
        clock.advance(0.004)
        fe.pump(force=True)
        assert t.latency is not None and t.latency >= 0.004

    def test_bad_shape_rejected(self):
        clock = FakeClock()
        _, fe = self._mk(clock)
        with pytest.raises(ValueError):
            fe.submit(np.zeros((3, 6), np.float32))

    def test_single_tenant_guardrail(self):
        from repro.serve.engine import Guardrail, GuardrailConfig
        from repro.serve.frontend import FrontEnd, FrontEndConfig
        clock = FakeClock()
        g = Guardrail(GuardrailConfig(d_model=6, num_bits=5, num_tables=4,
                                      warmup_items=16.0,
                                      fail_policy="fail_closed"))
        fe = FrontEnd(g, FrontEndConfig(batch_size=4, seq=2, d_model=6,
                                        max_queue=2), clock=clock)
        tickets = [fe.submit(self._embed(i)) for i in range(4)]
        shed = [t for t in tickets if t.status == "shed"]
        assert len(shed) == 2
        assert all(t.admitted is False for t in shed)   # fail_closed
        fe.drain()


# ---------------------------------------------------------------------------
# checkpoint step ordering (satellite 1 regression)
# ---------------------------------------------------------------------------

class TestCheckpointStepOrdering:
    def _tree(self, v):
        return {"x": np.full((4,), v, np.float32)}

    def test_numeric_not_lexicographic(self, tmp_path):
        d = str(tmp_path)
        for step in (2, 9, 10):
            ckpt.save(d, step, self._tree(step))
        # strip the zero padding: lexicographically "step_10" < "step_2"
        for step in (2, 9):
            os.rename(os.path.join(d, f"step_{step:010d}"),
                      os.path.join(d, f"step_{step}"))
        assert ckpt.all_steps(d) == [2, 9, 10]
        assert ckpt.latest_step(d) == 10
        tree, manifest = ckpt.CheckpointManager(d).restore_latest(
            self._tree(0))
        assert manifest["step"] == 10
        assert float(np.asarray(tree["x"])[0]) == 10.0

    def test_restore_resolves_unpadded_dirs(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 9, self._tree(9))
        os.rename(os.path.join(d, f"step_{9:010d}"),
                  os.path.join(d, "step_9"))
        tree, manifest = ckpt.restore(d, 9, self._tree(0))
        assert manifest["step"] == 9

    def test_torn_newest_falls_back_across_unpadded(self, tmp_path):
        d = str(tmp_path)
        for step in (9, 10):
            ckpt.save(d, step, self._tree(step))
        os.rename(os.path.join(d, f"step_{9:010d}"),
                  os.path.join(d, "step_9"))
        inject.tear_checkpoint(d, 10)
        tree, manifest = ckpt.CheckpointManager(d).restore_latest(
            self._tree(0))
        assert manifest["step"] == 9

    def test_gc_keeps_numeric_newest(self, tmp_path):
        d = str(tmp_path)
        for step in (2, 9):
            ckpt.save(d, step, self._tree(step))
        for step in (2, 9):
            os.rename(os.path.join(d, f"step_{step:010d}"),
                      os.path.join(d, f"step_{step}"))
        ckpt.save(d, 10, self._tree(10), keep=2)
        assert ckpt.all_steps(d) == [9, 10]   # step 2 collected, 9 kept
        assert not os.path.exists(os.path.join(d, "step_2"))


# ---------------------------------------------------------------------------
# autotune persistent cache (satellite 2)
# ---------------------------------------------------------------------------

class TestAutotunePersistentCache:
    @pytest.fixture(autouse=True)
    def _isolate(self, tmp_path, monkeypatch):
        from repro.kernels import runtime as rt
        saved_cache = dict(rt._AUTOTUNE_CACHE)
        saved_probe = rt._PROBED_BACKEND
        rt._AUTOTUNE_CACHE.clear()
        monkeypatch.setenv(rt._CACHE_DIR_ENV, str(tmp_path))
        yield
        rt._AUTOTUNE_CACHE.clear()
        rt._AUTOTUNE_CACHE.update(saved_cache)
        rt._PROBED_BACKEND = saved_probe

    @staticmethod
    def _slow_bench(times):
        import time as _t

        def bench(c):
            _t.sleep(times[c])
            return jnp.zeros(())

        return bench

    def test_winner_persists_across_cache_clear(self):
        from repro.kernels import runtime as rt
        calls = []

        def bench(c):
            calls.append(c)
            return self._slow_bench({8: 0.003, 16: 0.0, 32: 0.003})(c)

        assert rt.autotune("unit", ("persist",), True,
                           [8, 16, 32], bench, reps=1) == 16
        assert calls
        n_calls = len(calls)
        rt._AUTOTUNE_CACHE.clear()              # "new process"
        # bench_fn=None would normally return the first candidate; the
        # persisted winner must short-circuit it without re-benching
        assert rt.autotune("unit", ("persist",), True,
                           [8, 16, 32], None) == 16
        assert len(calls) == n_calls

    def test_corrupt_cache_file_is_a_miss(self, tmp_path):
        from repro.kernels import runtime as rt
        rt.autotune("unit", ("corrupt",), True, [4, 8],
                    self._slow_bench({4: 0.0, 8: 0.003}), reps=1)
        files = [f for f in os.listdir(tmp_path) if f.startswith("tune_")]
        assert files
        for f in files:
            with open(os.path.join(tmp_path, f), "w") as fh:
                fh.write("{not json")
        rt._AUTOTUNE_CACHE.clear()
        assert rt.autotune("unit", ("corrupt",), True,
                           [4, 8], None) == 4   # default, no crash

    def test_stale_winner_outside_candidates_ignored(self):
        from repro.kernels import runtime as rt
        rt.autotune("unit", ("stale",), True, [8, 16],
                    self._slow_bench({8: 0.0, 16: 0.003}), reps=1)
        rt._AUTOTUNE_CACHE.clear()
        # candidate space changed (new jax version, new shapes): the
        # persisted winner 8 is gone — must re-pick, not crash
        assert rt.autotune("unit", ("stale",), True, [32, 64], None) == 32

    def test_no_env_no_files(self, tmp_path, monkeypatch):
        from repro.kernels import runtime as rt
        monkeypatch.delenv(rt._CACHE_DIR_ENV, raising=False)
        rt.autotune("unit", ("noenv",), True, [4, 8],
                    self._slow_bench({4: 0.0, 8: 0.001}), reps=1)
        assert not any(f.startswith("tune_")
                       for f in os.listdir(tmp_path))

    def test_probe_backend_memoized(self):
        from repro.kernels import runtime as rt
        rt.reset_runtime_state()
        b1 = rt.probe_backend()
        assert b1 == jax.default_backend()
        assert rt.probe_backend() is b1
