"""Device-resident guardrail admission: masked-insert equivalence, the
one-executable compile contract, layout parity on a 1×2 CPU mesh, and the
fused-kernel path agreeing with the jnp reference path."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import assert_allclose_dtype
from repro.core import sketch as sk
from repro.core.sketch import AceConfig
from repro.serve.engine import Guardrail, GuardrailConfig

jax.config.update("jax_platform_name", "cpu")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 2, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def _seeded_state(cfg: AceConfig, seed: int, n_prior: int = 30):
    """A sketch with a prior batch inserted so n > 0 and σ is live."""
    rng = np.random.default_rng(seed)
    w = sk.make_params(cfg)
    x = jnp.asarray(rng.normal(size=(n_prior, cfg.dim)), jnp.float32)
    return sk.insert(sk.init(cfg), w, x, cfg), w, rng


class TestMaskedInsertEquivalence:
    @settings(max_examples=20, deadline=None)
    @given(B=st.integers(1, 48), K=st.integers(3, 8), L=st.integers(1, 12),
           seed=st.integers(0, 1000), density=st.integers(0, 10))
    def test_masked_equals_gather_insert(self, B, K, L, seed, density):
        """insert_buckets_masked(mask) ≡ insert_buckets(buckets[mask]):
        counts/n/μ exact, Welford within float tolerance."""
        cfg = AceConfig(dim=6, num_bits=K, num_tables=L, seed=seed % 7,
                        welford_min_n=float(seed % 3) * 8.0)
        state, _, rng = _seeded_state(cfg, seed)
        buckets = jnp.asarray(
            rng.integers(0, 1 << K, size=(B, L)), jnp.int32)
        mask_np = rng.random(B) < density / 10.0
        mask = jnp.asarray(mask_np)

        got = sk.insert_buckets_masked(state, buckets, mask, cfg)
        if mask_np.any():
            want = sk.insert_buckets(state, buckets[mask_np], cfg)
            assert bool(jnp.all(got.counts == want.counts))
            assert float(got.n) == float(want.n)
            assert float(sk.mean_mu(got)) == float(sk.mean_mu(want))
            assert_allclose_dtype(got.welford_mean, want.welford_mean)
            assert_allclose_dtype(got.welford_m2, want.welford_m2,
                                  rtol=1e-4, atol=1e-7)
        else:
            # empty admit: state must be untouched (the dense path would
            # NaN on a (0, L) batch — the masked path must not)
            assert bool(jnp.all(got.counts == state.counts))
            assert float(got.n) == float(state.n)
            assert float(got.welford_mean) == float(state.welford_mean)
            assert float(got.welford_m2) == float(state.welford_m2)

    def test_all_true_mask_is_plain_insert(self):
        cfg = AceConfig(dim=6, num_bits=6, num_tables=10, seed=0)
        state, _, rng = _seeded_state(cfg, 5)
        buckets = jnp.asarray(rng.integers(0, 64, size=(20, 10)), jnp.int32)
        got = sk.insert_buckets_masked(state, buckets,
                                       jnp.ones(20, bool), cfg)
        want = sk.insert_buckets(state, buckets, cfg)
        assert bool(jnp.all(got.counts == want.counts))
        assert float(got.n) == float(want.n)
        assert_allclose_dtype(got.welford_mean, want.welford_mean)
        assert_allclose_dtype(got.welford_m2, want.welford_m2)


class TestAdmitThreshold:
    def test_warmup_is_minus_inf(self):
        cfg = AceConfig(dim=4, num_bits=4, num_tables=4, seed=0)
        state = sk.init(cfg)
        t = sk.admit_threshold(state, alpha=2.0, warmup_items=10.0)
        assert float(t) == float("-inf")

    def test_armed_matches_rate_rule(self):
        cfg = AceConfig(dim=6, num_bits=5, num_tables=6, seed=1)
        state, _, _ = _seeded_state(cfg, 3, n_prior=40)
        t = sk.admit_threshold(state, alpha=1.5, warmup_items=10.0)
        want = (float(sk.mean_rate(state))
                - 1.5 * float(sk.sigma_welford(state))) * float(state.n)
        assert_allclose_dtype(t, np.float32(want))


class TestGuardrailCompileOnce:
    @pytest.mark.parametrize("use_kernels", [False, True])
    def test_traces_once_across_varying_admitted_counts(self, use_kernels):
        """The regression this PR exists for: the pre-PR admit retraced on
        every distinct admitted-count (data-dependent gather shape); the
        masked insert is fixed-shape, so exactly ONE trace serves them
        all."""
        g = Guardrail(GuardrailConfig(d_model=12, num_bits=6, num_tables=8,
                                      warmup_items=48.0, alpha=3.0),
                      use_kernels=use_kernels)
        rng = np.random.default_rng(7)
        base_dir = rng.normal(size=16)
        admitted = []
        for i in range(10):
            e = rng.normal(size=(24, 3, 12)).astype(np.float32) * 0.05
            e += base_dir[:12] * 2.0          # tight in-distribution cluster
            if i >= 3:                        # growing OOD fraction
                k = min(3 * (i - 2), 24)
                e[:k] = rng.normal(size=(k, 3, 12)) * 4.0
            mask = g.admit(jnp.asarray(e))
            assert mask.shape == (24,) and mask.dtype == np.bool_
            admitted.append(int(mask.sum()))
        assert g.trace_count == 1, admitted
        assert len(set(admitted)) > 1, (
            f"test vacuous: admitted counts never varied ({admitted})")

    def test_state_stays_on_device(self):
        """At most one host transfer per batch: the returned mask.  The
        sketch state threading through admit must remain jax Arrays (no
        np round-trip of counts/n)."""
        g = Guardrail(GuardrailConfig(d_model=8, num_bits=5, num_tables=4,
                                      warmup_items=8.0))
        e = jnp.asarray(np.random.default_rng(0).normal(size=(8, 2, 8)),
                        jnp.float32)
        mask = g.admit(e)
        assert isinstance(mask, np.ndarray)
        assert isinstance(g.state.counts, jax.Array)
        assert isinstance(g.state.n, jax.Array)

    def test_kernel_path_matches_reference_path(self):
        """Kernel vs jnp admit paths.  The kernel's tiled hash may flip a
        sign where |proj| ~ 0 (the srp kernels' documented 0.1% bucket
        tolerance), so masks get a tiny slack; with zero flips — the case
        on this toolchain — the downstream state must match exactly."""
        cfgkw = dict(d_model=12, num_bits=6, num_tables=8,
                     warmup_items=32.0, alpha=2.0)
        gj = Guardrail(GuardrailConfig(**cfgkw))
        gk = Guardrail(GuardrailConfig(**cfgkw), use_kernels=True)
        rng = np.random.default_rng(11)
        mismatch, total = 0, 0
        for i in range(6):
            e = jnp.asarray(rng.normal(size=(16, 3, 12)), jnp.float32)
            mj, mk = gj.admit(e), gk.admit(e)
            mismatch += int((mj != mk).sum())
            total += mj.size
        assert mismatch / total < 0.01, f"{mismatch}/{total} masks differ"
        assert abs(float(gj.state.n) - float(gk.state.n)) <= mismatch
        if mismatch == 0:
            assert bool(jnp.all(gj.state.counts == gk.state.counts))
            np.testing.assert_allclose(float(gj.state.welford_m2),
                                       float(gk.state.welford_m2),
                                       rtol=1e-5)

    def test_kernels_plus_mesh_rejected(self):
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
        with pytest.raises(ValueError):
            Guardrail(GuardrailConfig(d_model=8), mesh=mesh,
                      use_kernels=True)


class TestMaskedLayoutParity:
    @pytest.mark.slow
    def test_masked_insert_replicated_vs_table_sharded(self):
        """The masked insert keeps the replicated↔table-sharded parity
        contract: counts/n bitwise, Welford to float32 round-off, on the
        1×2 CPU mesh."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import sketch as sk
            from repro.core.sketch import AceConfig
            from repro.dist.sketch_parallel import (
                make_masked_update, make_table_sharded_masked_update,
                table_sharded_shardings)

            cfg = AceConfig(dim=8, num_bits=6, num_tables=10, seed=0,
                            welford_min_n=16.0)
            mesh = jax.make_mesh((1, 2), ("data", "model"))
            w = sk.make_params(cfg)
            rng = np.random.default_rng(0)
            xs = [jnp.asarray(rng.normal(size=(48, 8)), jnp.float32)
                  for _ in range(3)]
            masks = [jnp.asarray(rng.random(48) < p) for p in (1.0, .6, .3)]

            ref = sk.init(cfg)
            for x, m in zip(xs, masks):
                bk = sk.hash_buckets(x, w, cfg.srp)
                ref = sk.insert_buckets_masked(ref, bk, m, cfg)

            rep_upd = make_masked_update(mesh, cfg)
            ts_upd = make_table_sharded_masked_update(mesh, cfg)
            with jax.set_mesh(mesh):
                rep = sk.init(cfg)
                ts = jax.device_put(sk.init(cfg),
                                    table_sharded_shardings(mesh))
                for x, m in zip(xs, masks):
                    rep = rep_upd(rep, x, w, m)
                    ts = ts_upd(ts, x, w, m)

            for name, got in (("replicated", rep), ("table_sharded", ts)):
                assert bool(jnp.all(jnp.asarray(got.counts)
                                    == ref.counts)), name + " counts"
                assert float(got.n) == float(ref.n), name + " n"
                np.testing.assert_allclose(float(got.welford_mean),
                                           float(ref.welford_mean),
                                           rtol=1e-6)
                np.testing.assert_allclose(float(got.welford_m2),
                                           float(ref.welford_m2), rtol=1e-6)
            assert bool(jnp.all(jnp.asarray(ts.counts)
                                == jnp.asarray(rep.counts)))
            print("MASKED_PARITY_OK")
        """)
        assert "MASKED_PARITY_OK" in out

    @pytest.mark.slow
    def test_guardrail_admit_table_sharded_jit_mode(self):
        """Guardrail.admit (jit/SPMD mode) keeps the table-sharded
        placement through the masked insert and still traces once."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            import repro.core.sketch  # set_mesh shim
            from repro.serve.engine import Guardrail, GuardrailConfig

            mesh = jax.make_mesh((1, 2), ("data", "model"))
            g = Guardrail(GuardrailConfig(d_model=16, num_bits=6,
                                          num_tables=8, warmup_items=32.0),
                          mesh=mesh, sketch_layout="table_sharded")
            rng = np.random.default_rng(0)
            for _ in range(4):
                m = g.admit(jnp.asarray(rng.normal(size=(16, 4, 16)),
                                        jnp.float32))
            assert g.trace_count == 1, g.trace_count
            spec = g.state.counts.sharding.spec
            assert tuple(spec)[0] == "model", spec
            assert float(g.state.n) == 64.0
            print("SHARDED_ADMIT_OK", spec)
        """)
        assert "SHARDED_ADMIT_OK" in out
