"""Property suite for the quantile histogram's tail edges.

ISSUE-10 satellite: ``hist_quantile``/``quantile_threshold`` are the
admission path for every ``threshold_mode="quantile"`` filter, and their
edge behaviour (q near 1 with overflow-bin mass, empty histograms,
q-monotonicity under arbitrary nonnegative weightings) had no dedicated
coverage.  Runs under real ``hypothesis`` when the environment has it
(the conftest shim otherwise), AND against a seeded deterministic
corpus that exercises the same checks in every environment.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.quantile.sketch import (NUM_BINS, bin_edges, hist_quantile,
                                   quantile_threshold)

# Real hypothesis when installed; otherwise the deterministic shim from
# tests/conftest.py (keyword @given + st.integers only — so properties
# are stated over drawn seeds/scaled ints, the suite-wide idiom).
from hypothesis import given, settings
from hypothesis import strategies as st


# ---------------------------------------------------------------------------
# corpus: either hypothesis strategies or a seeded deterministic sweep
# ---------------------------------------------------------------------------

def _rand_hist(rng) -> np.ndarray:
    """A random nonnegative histogram: dense, sparse, or spiky; with or
    without underflow/overflow mass; sometimes float γ-decay weights."""
    kind = rng.integers(0, 4)
    h = rng.integers(0, 64, size=NUM_BINS).astype(np.float32)
    if kind == 1:                                   # sparse
        h *= (rng.random(NUM_BINS) < 0.1).astype(np.float32)
    elif kind == 2:                                 # one spike
        h[:] = 0.0
        h[rng.integers(0, NUM_BINS)] = float(rng.integers(1, 1000))
    elif kind == 3:                                 # γ-decayed weights
        h *= rng.random(NUM_BINS).astype(np.float32)
    return h


def _corpus(n=64, seed=1234):
    rng = np.random.default_rng(seed)
    return [(_rand_hist(rng), float(rng.random()), float(rng.random()))
            for _ in range(n)]


# ---------------------------------------------------------------------------
# the properties (shared by both drivers)
# ---------------------------------------------------------------------------

def check_monotone_in_q(hist: np.ndarray, qa: float, qb: float):
    """Q_q is non-decreasing in q for ANY nonnegative weighting."""
    lo, hi = sorted((qa, qb))
    vlo = float(hist_quantile(jnp.asarray(hist), lo))
    vhi = float(hist_quantile(jnp.asarray(hist), hi))
    assert vlo <= vhi + 1e-6, (lo, hi, vlo, vhi)


def check_bounded_by_edges(hist: np.ndarray, q: float):
    """Any quantile of a non-empty histogram lands inside the edge
    ladder [0, 1.5] — never NaN, never negative, never past the
    overflow bin's upper edge."""
    v = float(hist_quantile(jnp.asarray(hist), q))
    assert np.isfinite(v)
    assert 0.0 <= v <= float(bin_edges()[-1]) + 1e-6, (q, v)


def check_overflow_tail(hist: np.ndarray):
    """q → 1.0 with overflow-bin mass must return a rate from the
    overflow bin [1, 1.5] (a threshold ≥ every representable real rate)
    — NOT a value from the interior ladder.  Guards the exact tail the
    heavy-hitter streams exercise: saturating rates ≥ 1 land in the
    last bin and a q≈1 threshold must chase them there."""
    h = hist.copy()
    h[NUM_BINS - 1] = max(h[NUM_BINS - 1], 7.0)    # force overflow mass
    v = float(hist_quantile(jnp.asarray(h), 1.0))
    edges = np.asarray(bin_edges())
    assert edges[NUM_BINS - 1] <= v <= edges[NUM_BINS] + 1e-6, v
    # ...and without ANY overflow mass, q=1.0 stays on the real ladder
    h[NUM_BINS - 1] = 0.0
    if h.sum() > 0:
        v2 = float(hist_quantile(jnp.asarray(h), 1.0))
        assert v2 <= edges[NUM_BINS - 1] + 1e-6, v2


def check_empty_guard(q: float):
    """An all-zero histogram returns exactly 0.0 (no 0/0 NaN), and the
    score-space threshold stays −inf through warmup."""
    z = jnp.zeros((NUM_BINS,), jnp.float32)
    assert float(hist_quantile(z, q)) == 0.0
    t = quantile_threshold(z, jnp.float32(0.0), q, warmup_items=64.0)
    assert float(t) == -np.inf
    # armed (past warmup) but still-empty histogram: threshold 0, not NaN
    t2 = quantile_threshold(z, jnp.float32(128.0), q, warmup_items=64.0)
    assert float(t2) == 0.0


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

# q drawn as parts-per-million so the shim's integer-only strategy
# covers the full closed interval [0, 1] including both endpoints
_QI = st.integers(min_value=0, max_value=1_000_000)
_SEED = st.integers(min_value=0, max_value=2**31 - 1)


class TestQuantilePropsHypothesis:
    @settings(max_examples=40, deadline=None)
    @given(seed=_SEED, qa=_QI, qb=_QI)
    def test_monotone_in_q(self, seed, qa, qb):
        h = _rand_hist(np.random.default_rng(seed))
        check_monotone_in_q(h, qa / 1e6, qb / 1e6)

    @settings(max_examples=40, deadline=None)
    @given(seed=_SEED, q=_QI)
    def test_bounded_by_edges(self, seed, q):
        check_bounded_by_edges(_rand_hist(np.random.default_rng(seed)),
                               q / 1e6)

    @settings(max_examples=40, deadline=None)
    @given(seed=_SEED)
    def test_overflow_tail(self, seed):
        check_overflow_tail(_rand_hist(np.random.default_rng(seed)))

    @settings(max_examples=15, deadline=None)
    @given(q=_QI)
    def test_empty_guard(self, q):
        check_empty_guard(q / 1e6)


class TestQuantilePropsCorpus:
    """Seeded deterministic corpus — runs in EVERY environment (the
    hypothesis class above is the richer generator when available)."""

    @pytest.mark.parametrize("i", range(0, 64, 8))
    def test_monotone_in_q(self, i):
        for h, qa, qb in _corpus()[i:i + 8]:
            check_monotone_in_q(h, qa, qb)

    @pytest.mark.parametrize("i", range(0, 64, 8))
    def test_bounded_by_edges(self, i):
        for h, q, _ in _corpus()[i:i + 8]:
            check_bounded_by_edges(h, q)

    def test_overflow_tail(self):
        for h, _, _ in _corpus(32, seed=77):
            check_overflow_tail(h)

    def test_empty_guard(self):
        for q in (0.0, 0.01, 0.5, 0.99, 1.0):
            check_empty_guard(q)

    def test_exact_tail_pins(self):
        """Hand-pinned tail cases (no randomness): all mass in the
        overflow bin ⇒ every q lands in [1, 1.5]; all mass in the
        underflow bin ⇒ every q lands in [0, RATE_MIN]."""
        edges = np.asarray(bin_edges())
        over = np.zeros(NUM_BINS, np.float32)
        over[-1] = 5.0
        under = np.zeros(NUM_BINS, np.float32)
        under[0] = 5.0
        for q in (0.01, 0.5, 0.999, 1.0):
            vo = float(hist_quantile(jnp.asarray(over), q))
            assert edges[NUM_BINS - 1] <= vo <= edges[NUM_BINS] + 1e-6
            vu = float(hist_quantile(jnp.asarray(under), q))
            assert 0.0 <= vu <= edges[1] + 1e-9
