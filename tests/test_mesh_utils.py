"""Unit tests for the sharding utilities that §Perf iterations rely on:
divisibility sanitisation, FSDP assignment, roofline parsing."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.hlo_analysis import _shape_bytes, collective_bytes_by_kind
from repro.launch.mesh import apply_fsdp, rules_for, sanitize_pspec

jax.config.update("jax_platform_name", "cpu")


class _MeshStub:
    """sanitize_pspec/apply_fsdp/rules_for only read axis_names and
    devices.shape — a stub avoids needing 8 fake devices in-process."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.zeros(shape)


@pytest.fixture(scope="module")
def mesh():
    return _MeshStub((2, 4), ("data", "model"))


class TestSanitize:
    def test_divisible_kept(self, mesh):
        ps = sanitize_pspec(P(None, "model"), (3, 8), mesh)
        assert tuple(ps) == (None, "model")

    def test_indivisible_dropped(self, mesh):
        # 6 heads cannot shard over model=4
        ps = sanitize_pspec(P(None, "model", None), (2, 6, 16), mesh)
        assert tuple(ps) == (None, None, None) or tuple(ps) == (None, None)

    def test_tuple_axes(self, mesh):
        ps = sanitize_pspec(P(("data", "model")), (8,), mesh)
        assert tuple(ps) == (("data", "model"),)
        ps = sanitize_pspec(P(("data", "model")), (6,), mesh)
        assert tuple(ps)[0] is None


class TestFsdp:
    def test_assigns_largest_free_dim(self, mesh):
        ps = apply_fsdp(P(None, "model"), (64, 8), mesh, axis="data")
        assert tuple(ps) == ("data", "model")

    def test_skips_if_already_on_axis(self, mesh):
        ps = apply_fsdp(P("data", "model"), (64, 8), mesh, axis="data")
        assert tuple(ps) == ("data", "model")

    def test_skips_indivisible(self, mesh):
        ps = apply_fsdp(P(None,), (7,), mesh, axis="data")
        assert tuple(ps) in ((None,), ())

    def test_missing_axis_noop(self, mesh):
        ps = apply_fsdp(P(None,), (8,), mesh, axis="pod")
        assert tuple(ps) in ((None,), ())


class TestRules:
    def test_long_context_moves_cache_to_seq(self, mesh):
        r_norm = rules_for(mesh, long_context=False)
        r_long = rules_for(mesh, long_context=True)
        assert r_norm["batch"] is not None and r_norm["cache_seq"] is None
        assert r_long["batch"] is None and r_long["cache_seq"] is not None


class TestHloParsing:
    def test_shape_bytes(self):
        assert _shape_bytes("bf16[4,8]") == 64
        assert _shape_bytes("f32[2,2]") == 16
        assert _shape_bytes("(f32[4], s32[2])") == 24

    def test_collective_extraction(self):
        hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[16]{0} all-reduce-start(%y)
  %cp = f32[4,4]{1,0} collective-permute(%z)
  %not_a_match = f32[9] add(%a, %b)
"""
        out = collective_bytes_by_kind(hlo)
        assert out["all-gather"]["bytes"] == 8 * 128 * 2
        assert out["collective-permute"]["bytes"] == 64
        assert out["total_bytes"] > 0

    @pytest.mark.slow
    def test_real_compiled_module(self):
        """End-to-end: an 8-device psum module reports all-reduce bytes."""
        import subprocess, sys, os, textwrap
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env["PYTHONPATH"] = os.path.join(repo, "src")
        code = textwrap.dedent("""
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.dist.hlo_analysis import collective_bytes_by_kind
            mesh = jax.make_mesh((8,), ("data",))
            sh = NamedSharding(mesh, P("data"))
            rep = NamedSharding(mesh, P())
            with jax.set_mesh(mesh):
                f = jax.jit(lambda x: jnp.sum(x, axis=0),
                            in_shardings=sh, out_shardings=rep)
                comp = f.lower(
                    jax.ShapeDtypeStruct((64, 32), jnp.float32)).compile()
            out = collective_bytes_by_kind(comp.as_text())
            assert out["total_bytes"] > 0, out
            print("PARSE_OK", out["total_bytes"])
        """)
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=180)
        assert r.returncode == 0, r.stderr[-1500:]
        assert "PARSE_OK" in r.stdout
