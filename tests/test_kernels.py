"""Per-kernel validation.

``TestKernelParityMatrix`` is the ONE kernel-vs-reference sweep: every
kernel × the hash family feeding it (dense matmul vs SRHT) × how
interpret mode is resolved (the ``runtime`` resolver default vs pinned
``interpret=True``), over a set of deliberately awkward shapes.  Adding
a kernel means adding one runner entry, not a new copy-pasted
``test_matches_ref`` — the window-combine kernel rides the same matrix.

The per-kernel classes below keep only what the matrix can't express:
dtype behaviour, tiling invariance, collision/padding edge cases, mode
break-evens, and the ops-level dispatch contracts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import assert_allclose_dtype
from repro.core.sketch import AceConfig
from repro.core.srp import SrpConfig, hash_buckets, make_projections
from repro.kernels import ref as R
from repro.kernels import ops
from repro.kernels.ace_admit_fused import ace_admit_fused
from repro.kernels.ace_fleet_score import ace_fleet_score
from repro.kernels.ace_query import ace_query
from repro.kernels.ace_score_fused import ace_score_fused
from repro.kernels.ace_update import (HIST_MAX_BUCKETS, ace_update,
                                      choose_mode)
from repro.kernels.ace_window_combine import (FLAT_MAX_COLS,
                                              ace_window_combine)
from repro.kernels.ace_window_combine import choose_mode as window_mode
from repro.kernels.srht_hash import srht_hash
from repro.kernels.srp_hash import srp_hash

jax.config.update("jax_platform_name", "cpu")


def _x(B, d, seed=0, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(B, d)), dtype)


SHAPES = [
    # (B, d, K, L) — deliberately awkward: non-multiples of 8/128, L>B, tiny.
    (16, 32, 8, 10),
    (100, 300, 15, 50),   # paper's K, L
    (7, 9, 4, 3),
    (1, 257, 10, 20),
    (33, 128, 12, 50),
    (256, 64, 6, 7),
]

# Trimmed sweep for the full parity matrix (every kernel × hash family ×
# interpret resolution); the paper-scale shape is the heavyweight and
# rides the slow lane.
MATRIX_SHAPES = [
    (16, 32, 8, 10),
    (7, 9, 4, 3),
    (33, 128, 12, 50),
    pytest.param(100, 300, 15, 50, marks=pytest.mark.slow),
]

# (hash_mode feeding the kernel, interpret argument): None exercises the
# repro.kernels.runtime resolver (env var / backend probe — interpret on
# this CPU container), True pins it explicitly; both must agree.
MODES = [("dense", None), ("srht", None),
         pytest.param("dense", True, marks=pytest.mark.slow),
         pytest.param("srht", True, marks=pytest.mark.slow)]


class TestKernelParityMatrix:
    """kernel × hash family × interpret resolution × shape, one sweep."""

    def _cfg(self, d, K, L, hash_mode, seed):
        return SrpConfig(dim=d, num_bits=K, num_tables=L, seed=seed,
                         hash_mode=hash_mode)

    def _data(self, B, d, K, L, hash_mode, seed=0):
        cfg = self._cfg(d, K, L, hash_mode, seed + 1)
        w = make_projections(cfg)
        x = _x(B, d, seed=seed + 2)
        rng = np.random.default_rng(seed + 3)
        counts = jnp.asarray(rng.integers(0, 9, size=(L, 1 << K)),
                             jnp.int32)
        buckets = hash_buckets(x, w, cfg)     # family-realistic ids
        return cfg, w, x, counts, buckets

    @pytest.mark.parametrize("hash_mode,interpret", MODES)
    @pytest.mark.parametrize("B,d,K,L", MATRIX_SHAPES)
    def test_hash(self, B, d, K, L, hash_mode, interpret):
        """srp_hash / srht_hash kernels ≡ the jnp hash_buckets dispatch,
        bitwise (f32)."""
        cfg, w, x, _counts, _b = self._data(B, d, K, L, hash_mode)
        if hash_mode == "srht":
            got = srht_hash(x, cfg, interpret=interpret)
        else:
            got = srp_hash(x, w, cfg, interpret=interpret)
        want = hash_buckets(x, w, cfg)
        assert got.shape == (B, L) and got.dtype == jnp.int32
        assert bool(jnp.all(got == want))

    @pytest.mark.parametrize("hash_mode,interpret", MODES)
    @pytest.mark.parametrize("B,d,K,L", MATRIX_SHAPES)
    def test_update(self, B, d, K, L, hash_mode, interpret):
        """ace_update ≡ histogram scatter-add, exactly (both bucket-id
        families as input distributions)."""
        _cfg, _w, _x_, counts, buckets = self._data(B, d, K, L, hash_mode)
        got = ace_update(counts, buckets, interpret=interpret)
        want = R.ace_update_ref(counts, buckets)
        assert bool(jnp.all(got == want))

    @pytest.mark.parametrize("hash_mode,interpret", MODES)
    @pytest.mark.parametrize("B,d,K,L", MATRIX_SHAPES)
    def test_query(self, B, d, K, L, hash_mode, interpret):
        """ace_query gathered counts ≡ fancy-index gather, exactly."""
        _cfg, _w, _x_, counts, buckets = self._data(B, d, K, L, hash_mode)
        got = ace_query(counts, buckets, interpret=interpret)
        want = R.ace_query_ref(counts, buckets)
        assert bool(jnp.all(got == want))

    @pytest.mark.parametrize("hash_mode,interpret", MODES)
    @pytest.mark.parametrize("B,d,K,L", MATRIX_SHAPES)
    def test_score(self, B, d, K, L, hash_mode, interpret):
        """Fused scoring (one launch under dense; SRHT-hash + gather
        kernels under srht) ≡ hash→gather→mean reference, to float
        reduction order."""
        cfg, w, x, counts, _b = self._data(B, d, K, L, hash_mode)
        if hash_mode == "srht":
            got = jnp.mean(ace_query(
                counts, srht_hash(x, cfg, interpret=interpret),
                interpret=interpret), axis=-1)
        else:
            got = ace_score_fused(counts, x, w, cfg, interpret=interpret)
        want = R.ace_score_ref(counts, x, w, cfg)
        assert_allclose_dtype(got, want, rtol=1e-6)

    @pytest.mark.parametrize("hash_mode,interpret", MODES)
    @pytest.mark.parametrize("B,d,K,L", MATRIX_SHAPES)
    def test_admit(self, B, d, K, L, hash_mode, interpret):
        """Fused admission vs the reference: bucket draw agreement (the
        in-kernel dense hash may flip a measure-zero sign), then exact
        masked insert downstream of the kernel's own buckets."""
        cfg, w, x, counts, _b = self._data(B, d, K, L, hash_mode)
        pre = R.ace_score_ref(counts, x, w, cfg)
        thresh = jnp.float32(np.median(np.asarray(pre)))
        if hash_mode == "srht":
            # srht admission path: bitwise-identical hash kernel + the
            # shared jnp score/threshold/insert helpers
            from repro.core import sketch as sk
            buckets = srht_hash(x, cfg, interpret=interpret)
            scores = sk.batch_scores(counts, buckets)
            admit = scores >= thresh
            nc = counts.at[
                jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None, :],
                                 buckets.shape), buckets].add(
                jnp.broadcast_to(admit.astype(counts.dtype)[:, None],
                                 buckets.shape))
        else:
            nc, scores, admit, buckets = ace_admit_fused(
                counts, x, w, thresh, cfg, interpret=interpret)
        want_nc, want_scores, want_admit, want_buckets = R.ace_admit_ref(
            counts, x, w, thresh, cfg)
        agree = float(jnp.mean(
            (buckets == want_buckets).astype(jnp.float32)))
        assert agree > 0.999
        # everything downstream of the kernel's own bucket draw is exact
        ref_nc, ref_scores, ref_admit, _ = self._admit_from_buckets(
            counts, buckets, thresh, L)
        assert_allclose_dtype(scores, ref_scores, rtol=1e-6)
        assert bool(jnp.all(admit == (scores >= thresh)))
        assert bool(jnp.all(nc == ref_nc)), "masked insert differs"

    @staticmethod
    def _admit_from_buckets(counts, buckets, thresh, L):
        gathered = R.ace_query_ref(counts, buckets)
        scores = jnp.sum(gathered, axis=-1) * jnp.float32(1.0 / L)
        admit = scores >= thresh
        rows = jnp.broadcast_to(
            jnp.arange(L, dtype=jnp.int32)[None, :], buckets.shape)
        nc = counts.at[rows, buckets].add(
            jnp.broadcast_to(admit.astype(counts.dtype)[:, None],
                             buckets.shape))
        return nc, scores, admit, buckets

    @pytest.mark.parametrize("hash_mode,interpret", MODES)
    @pytest.mark.parametrize("B,d,K,L", MATRIX_SHAPES)
    @pytest.mark.parametrize("T", [1, 5])
    def test_fleet_score(self, B, d, K, L, T, hash_mode, interpret):
        """Fused multi-tenant scoring (one launch under dense; SRHT hash
        kernel + jnp fleet gather under srht) ≡ the tenant-routed
        reference, to float reduction order; T=1 with zero ids must also
        equal the single-tenant fused score exactly (same reference)."""
        cfg, w, x, _c, _b = self._data(B, d, K, L, hash_mode)
        rng = np.random.default_rng(B + T)
        counts = jnp.asarray(rng.integers(0, 9, size=(T, L, 1 << K)),
                             jnp.int32)
        tids = jnp.asarray(rng.integers(0, T, size=(B,)), jnp.int32)
        if hash_mode == "srht":
            from repro.fleet.state import FleetState, fleet_scores
            st = FleetState(counts, jnp.zeros((T,)), jnp.zeros((T,)),
                            jnp.zeros((T,)))
            got = fleet_scores(st, tids,
                               srht_hash(x, cfg, interpret=interpret))
        else:
            got = ace_fleet_score(counts, x, tids, w, cfg,
                                  interpret=interpret)
        want = R.ace_fleet_score_ref(counts, x, tids, w, cfg)
        assert_allclose_dtype(got, want, rtol=1e-6)
        if T == 1 and hash_mode == "dense":
            single = ace_score_fused(counts[0], x, w, cfg,
                                     interpret=interpret)
            assert_allclose_dtype(got, single, rtol=1e-6)

    @pytest.mark.parametrize("hash_mode,interpret", MODES)
    @pytest.mark.parametrize("B,d,K,L", MATRIX_SHAPES)
    @pytest.mark.parametrize("T,E", [(1, 1), (3, 3),
                                     pytest.param(1, 3,
                                                  marks=pytest.mark.slow),
                                     pytest.param(3, 1,
                                                  marks=pytest.mark.slow)])
    def test_fleet_window_admit(self, B, d, K, L, T, E, hash_mode,
                                interpret):
        """ace_fleet_window_admit_fused ≡ the composed flat-admit →
        window-combine → fleet-score reference: bucket draw agreement,
        then EXACT ring/admit downstream of the kernel's own buckets
        (srht rows run the kernel-hash + jnp composition ops dispatches
        to — bitwise against the same reference)."""
        cfg, w, x, _c, _b = self._data(B, d, K, L, hash_mode)
        rng = np.random.default_rng(B + T + E)
        ring_counts = jnp.asarray(
            rng.integers(0, 9, size=(T, E, L, 1 << K)), jnp.int32)
        tail = jnp.asarray(rng.uniform(0, 4, size=(T, L, 1 << K)),
                           jnp.float32)
        cursor = jnp.asarray(rng.integers(0, E, size=(T,)), jnp.int32)
        tids = jnp.asarray(rng.integers(0, T, size=(B,)), jnp.int32)
        # thresholds straddling the score distribution, one per tenant
        pre = R.ace_fleet_window_admit_ref(
            ring_counts, tail, cursor, x, tids, w, jnp.zeros((T,)), cfg)[1]
        med = jnp.float32(np.median(np.asarray(pre)))
        thr = med + jnp.linspace(-0.5, 0.5, T).astype(jnp.float32)

        if hash_mode == "srht":
            # srht dispatch = srht hash kernel + the jnp fleet-window
            # composition (ops.ace_fleet_window_admit's srht branch);
            # the hash kernel is bitwise the jnp hash, so the composed
            # path IS the reference — assert the hash identity that
            # makes it so, and the composition itself at ops level
            # (TestOpsDispatch.test_ops_fleet_window_admit_srht_exact).
            buckets = srht_hash(x, cfg, interpret=interpret)
            assert bool(jnp.array_equal(buckets,
                                        hash_buckets(x, w, cfg)))
            return
        from repro.kernels.ace_fleet_window_admit import \
            ace_fleet_window_admit_fused
        new_ring, scores, admit, buckets, tail_sums, live_pre = \
            ace_fleet_window_admit_fused(ring_counts, tail, cursor, x,
                                         tids, w, thr, cfg,
                                         interpret=interpret)
        ref = R.ace_fleet_window_admit_ref(ring_counts, tail, cursor, x,
                                           tids, w, thr, cfg)
        agree = float(jnp.mean((buckets == ref[3]).astype(jnp.float32)))
        assert agree > 0.999
        # downstream of the kernel's own bucket draw: exact
        (want_ring, want_scores, want_admit, _wb, want_tail,
         want_live) = self._fleet_window_from_buckets(
            ring_counts, tail, cursor, tids, buckets, thr)
        assert_allclose_dtype(scores, want_scores, rtol=1e-6)
        assert_allclose_dtype(tail_sums, want_tail, rtol=1e-6)
        assert_allclose_dtype(live_pre, want_live, rtol=1e-6)
        assert bool(jnp.all(admit == (scores >= thr[tids])))
        re_ring = self._fleet_window_from_buckets(
            ring_counts, tail, cursor, tids, buckets, thr,
            admit=admit)[0]
        assert bool(jnp.all(new_ring == re_ring)), "masked insert differs"

    @staticmethod
    def _fleet_window_from_buckets(ring_counts, tail, cursor, tids,
                                   buckets, thr, admit=None):
        T, E, L, nb = ring_counts.shape
        iota_j = jnp.arange(L, dtype=jnp.int32)[None, :]
        tail_rows = tids[:, None] * L + iota_j
        tail_sums = jnp.sum(
            tail.reshape(T * L, nb)[tail_rows, buckets], axis=-1)
        ring_rows = (tids[:, None] * (E * L)
                     + cursor[tids][:, None] * L + iota_j)
        flat = ring_counts.reshape(T * E * L, nb)
        live_pre = jnp.sum(flat[ring_rows, buckets].astype(jnp.float32),
                           axis=-1)
        scores = (tail_sums + live_pre) * jnp.float32(1.0 / L)
        if admit is None:
            admit = scores >= thr[tids]
        w_ctr = jnp.broadcast_to(
            admit.astype(ring_counts.dtype)[:, None], buckets.shape)
        new_ring = flat.at[ring_rows, buckets].add(w_ctr) \
            .reshape(ring_counts.shape)
        return new_ring, scores, admit, buckets, tail_sums, live_pre

    @pytest.mark.parametrize("hash_mode,interpret", MODES)
    @pytest.mark.parametrize("B,d,K,L", MATRIX_SHAPES)
    @pytest.mark.parametrize("E", [1, 4])
    def test_window_combine(self, B, d, K, L, E, hash_mode, interpret):
        """ace_window_combine (E-way weighted gather+combine, one
        launch) ≡ the per-epoch reference, to float reduction order —
        both lowering modes."""
        _cfg, _w, _x_, _c, buckets = self._data(B, d, K, L, hash_mode)
        rng = np.random.default_rng(B + E)
        counts = jnp.asarray(rng.integers(0, 9, size=(E, L, 1 << K)),
                             jnp.int32)
        weights = jnp.asarray(0.7 ** rng.permutation(E), jnp.float32)
        want = R.ace_window_combine_ref(counts, buckets, weights)
        for mode in ("flat", "unroll", "auto"):
            got = ace_window_combine(counts, buckets, weights,
                                     interpret=interpret, mode=mode)
            assert_allclose_dtype(got, want, rtol=1e-6,
                                  err_msg=f"mode={mode}")


class TestSrpHashKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        cfg = SrpConfig(dim=64, num_bits=8, num_tables=10, seed=0)
        w = make_projections(cfg, dtype=dtype)
        x = _x(40, 64, dtype=dtype)
        got = srp_hash(x, w, cfg)
        want = R.srp_hash_ref(x, w, cfg)
        # bf16 sign flips only where |proj| underflows; require > 99% agree
        agree = float(jnp.mean((got == want).astype(jnp.float32)))
        assert agree > 0.99

    @pytest.mark.slow
    @settings(max_examples=15, deadline=None)
    @given(B=st.integers(1, 70), d=st.integers(2, 200),
           K=st.integers(1, 15), L=st.integers(1, 50))
    def test_property_sweep(self, B, d, K, L):
        cfg = SrpConfig(dim=d, num_bits=K, num_tables=L, seed=1)
        w = make_projections(cfg)
        x = _x(B, d, seed=B * d % 97)
        assert bool(jnp.all(srp_hash(x, w, cfg) == R.srp_hash_ref(x, w, cfg)))

    @pytest.mark.parametrize("bm,bk", [(8, 128), (64, 256), (256, 512)])
    def test_block_shape_invariance(self, bm, bk):
        """Result must not depend on the tiling choice."""
        cfg = SrpConfig(dim=200, num_bits=10, num_tables=30, seed=2)
        w = make_projections(cfg)
        x = _x(90, 200)
        assert bool(jnp.all(srp_hash(x, w, cfg, bm=bm, bk=bk) ==
                            R.srp_hash_ref(x, w, cfg)))


class TestAceUpdateKernel:
    def test_duplicate_buckets_accumulate(self):
        """Collision-safety: many items in one bucket must all count."""
        L, K, B = 4, 6, 32
        counts = jnp.zeros((L, 1 << K), jnp.int32)
        buckets = jnp.full((B, L), 5, jnp.int32)
        got = ace_update(counts, buckets)
        assert int(got[0, 5]) == B and int(got.sum()) == B * L

    @pytest.mark.parametrize("dtype", [jnp.int32, jnp.int16])
    def test_counter_dtypes(self, dtype):
        rng = np.random.default_rng(3)
        counts = jnp.zeros((8, 256), dtype)
        buckets = jnp.asarray(rng.integers(0, 256, size=(50, 8)), jnp.int32)
        got = ace_update(counts, buckets)
        want = R.ace_update_ref(counts, buckets)
        assert got.dtype == dtype and bool(jnp.all(got == want))

    @pytest.mark.parametrize("B,d,K,L", SHAPES)
    @pytest.mark.parametrize("mode", ["hist", "auto"])
    def test_hist_mode_matches_scalar(self, B, d, K, L, mode):
        """The vectorised one-hot histogram path is bit-identical to the
        scalar RMW loop (duplicates included)."""
        rng = np.random.default_rng(B + L)
        counts = jnp.asarray(rng.integers(0, 7, size=(L, 1 << K)), jnp.int32)
        buckets = jnp.asarray(rng.integers(0, 1 << K, size=(B, L)), jnp.int32)
        got = ace_update(counts, buckets, mode=mode)
        want = ace_update(counts, buckets, mode="scalar")
        assert bool(jnp.all(got == want))

    @pytest.mark.parametrize("dtype", [jnp.int32, jnp.int16])
    def test_hist_mode_counter_dtypes(self, dtype):
        rng = np.random.default_rng(4)
        counts = jnp.zeros((6, 128), dtype)
        buckets = jnp.asarray(rng.integers(0, 128, size=(80, 6)), jnp.int32)
        got = ace_update(counts, buckets, mode="hist")
        assert got.dtype == dtype
        assert bool(jnp.all(got == R.ace_update_ref(counts, buckets)))

    def test_auto_dispatch_break_even(self):
        """auto picks hist above the B·L break-even (small bucket space),
        scalar below it or when 2^K outgrows the VPU sweep."""
        assert choose_mode(256, 16, 1 << 10) == "hist"
        assert choose_mode(4, 8, 1 << 10) == "scalar"
        assert choose_mode(4096, 50, 2 * HIST_MAX_BUCKETS) == "scalar"


class TestFusedAdmitKernel:
    @pytest.mark.parametrize("t,expect", [(-np.inf, "all"), (np.inf, "none")])
    def test_threshold_extremes(self, t, expect):
        cfg = SrpConfig(dim=32, num_bits=6, num_tables=9, seed=2)
        w = make_projections(cfg)
        x = _x(21, 32, seed=3)
        counts = jnp.zeros((9, 64), jnp.int32)
        nc, scores, admit, _ = ace_admit_fused(counts, x, w, jnp.float32(t),
                                               cfg)
        if expect == "all":
            assert bool(jnp.all(admit)) and int(nc.sum()) == 21 * 9
        else:
            assert not bool(jnp.any(admit)) and int(nc.sum()) == 0

    def test_scores_are_pre_insert(self):
        """Scoring must see the counts BEFORE the masked insert mutates
        the aliased buffer (all items admitted, duplicates in play)."""
        cfg = SrpConfig(dim=16, num_bits=4, num_tables=5, seed=0)
        w = make_projections(cfg)
        x = jnp.broadcast_to(_x(1, 16, seed=4), (12, 16))  # 12 duplicates
        counts = jnp.zeros((5, 16), jnp.int32)
        nc, scores, admit, _ = ace_admit_fused(counts, x, w,
                                               jnp.float32(-np.inf), cfg)
        assert_allclose_dtype(scores, np.zeros(12, np.float32))
        assert int(nc.sum()) == 12 * 5   # but all 12 inserts landed

    def test_pad_rows_never_insert(self):
        """B not a multiple of 8: the pad rows hash garbage and must not
        leak into the histogram or the mask."""
        cfg = SrpConfig(dim=8, num_bits=5, num_tables=3, seed=1)
        w = make_projections(cfg)
        x = _x(5, 8, seed=5)
        counts = jnp.zeros((3, 32), jnp.int32)
        nc, scores, admit, buckets = ace_admit_fused(
            counts, x, w, jnp.float32(-np.inf), cfg)
        assert admit.shape == (5,) and scores.shape == (5,)
        assert int(nc.sum()) == 5 * 3


class TestAceQueryKernel:
    @pytest.mark.parametrize("mode", ["vector", "scalar"])
    def test_lowering_modes_agree(self, mode):
        rng = np.random.default_rng(6)
        counts = jnp.asarray(rng.integers(0, 9, size=(10, 256)), jnp.int32)
        buckets = jnp.asarray(rng.integers(0, 256, size=(40, 10)), jnp.int32)
        got = ace_query(counts, buckets, mode=mode)
        want = R.ace_query_ref(counts, buckets)
        assert bool(jnp.all(got == want))

    def test_batch_tiling_invariance(self):
        rng = np.random.default_rng(5)
        counts = jnp.asarray(rng.integers(0, 9, size=(10, 256)), jnp.int32)
        buckets = jnp.asarray(rng.integers(0, 256, size=(130, 10)), jnp.int32)
        a = ace_query(counts, buckets, bm=32)
        b = ace_query(counts, buckets, bm=1024)
        assert bool(jnp.all(a == b))


class TestFusedScoreKernel:
    def test_fused_equals_two_kernel_path(self):
        cfg = SrpConfig(dim=100, num_bits=10, num_tables=25, seed=4)
        w = make_projections(cfg)
        x = _x(77, 100)
        rng = np.random.default_rng(11)
        counts = jnp.asarray(rng.integers(0, 9, size=(25, 1024)), jnp.int32)
        fused = ace_score_fused(counts, x, w, cfg)
        two = jnp.mean(ace_query(counts, srp_hash(x, w, cfg)), axis=-1)
        assert_allclose_dtype(fused, two, rtol=1e-6)


class TestWindowCombineKernel:
    def test_batch_tiling_invariance(self):
        rng = np.random.default_rng(12)
        counts = jnp.asarray(rng.integers(0, 9, size=(3, 8, 128)), jnp.int32)
        buckets = jnp.asarray(rng.integers(0, 128, size=(70, 8)), jnp.int32)
        weights = jnp.asarray([1.0, 0.5, 0.25], jnp.float32)
        a = ace_window_combine(counts, buckets, weights, bm=16)
        b = ace_window_combine(counts, buckets, weights, bm=1024)
        assert bool(jnp.all(a == b))

    def test_auto_mode_break_even(self):
        assert window_mode(4, 50) == "flat"
        assert window_mode(FLAT_MAX_COLS // 50 + 1, 50) == "unroll"

    def test_single_epoch_unit_weight_is_plain_query_mean(self):
        """E=1, w=[1.0]: the windowed combine is the flat score."""
        rng = np.random.default_rng(13)
        counts = jnp.asarray(rng.integers(0, 9, size=(1, 6, 64)), jnp.int32)
        buckets = jnp.asarray(rng.integers(0, 64, size=(20, 6)), jnp.int32)
        got = ace_window_combine(counts, buckets,
                                 jnp.ones((1,), jnp.float32))
        want = jnp.sum(R.ace_query_ref(counts[0], buckets), axis=-1) \
            * jnp.float32(1.0 / 6)
        assert_allclose_dtype(got, want, rtol=1e-6)


class TestOpsDispatch:
    def test_ops_roundtrip_matches_sketch(self):
        """Kernel-path insert+score equals the pure-jnp sketch path."""
        from repro.core import sketch as sk
        cfg = AceConfig(dim=20, num_bits=8, num_tables=12, seed=6)
        w = sk.make_params(cfg)
        x = _x(64, 20)
        st_k = ops.ace_update(sk.init(cfg),
                              ops.srp_hash(x, w, cfg.srp), cfg)
        st_j = sk.insert(sk.init(cfg), w, x, cfg)
        assert bool(jnp.all(st_k.counts == st_j.counts))
        q = _x(16, 20, seed=1)
        assert_allclose_dtype(ops.ace_score(st_k, q, w, cfg),
                              sk.score(st_j, w, q, cfg), rtol=1e-6)

    def test_ops_admit_matches_sketch_masked_path(self):
        """Kernel-path admission equals hash→lookup→threshold→masked
        insert on the pure-jnp sketch path, Welford stream included."""
        from repro.core import sketch as sk
        from repro.core.srp import hash_buckets
        cfg = AceConfig(dim=14, num_bits=7, num_tables=10, seed=9,
                        welford_min_n=8.0)
        w = sk.make_params(cfg)
        st_k = st_j = sk.insert(sk.init(cfg), w, _x(40, 14, seed=2), cfg)
        for i in range(3):
            q = _x(24, 14, seed=3 + i)
            st_k, mask_k = ops.ace_admit(st_k, q, w, cfg, alpha=1.0,
                                         warmup_items=16.0)
            buckets = hash_buckets(q, w, cfg.srp)
            scores = sk.lookup(st_j, buckets)
            mask_j = scores >= sk.admit_threshold(st_j, 1.0, 16.0)
            st_j = sk.insert_buckets_masked(st_j, buckets, mask_j, cfg)
            assert bool(jnp.all(mask_k == mask_j))
        assert bool(jnp.all(st_k.counts == st_j.counts))
        assert float(st_k.n) == float(st_j.n)
        assert_allclose_dtype(st_k.welford_mean, st_j.welford_mean,
                              rtol=1e-6)
        assert_allclose_dtype(st_k.welford_m2, st_j.welford_m2,
                              rtol=1e-5)

    def test_ops_fleet_admit_matches_fleet_jnp_path(self):
        """Kernel-path fleet admission ≡ hash→route→threshold→insert on
        the pure-jnp fleet path, per-tenant Welford streams included."""
        from repro.core.srp import hash_buckets
        from repro.fleet import (FleetConfig, admit_thresholds,
                                 fleet_scores, init, insert_masked)
        cfg = AceConfig(dim=14, num_bits=7, num_tables=10, seed=9,
                        welford_min_n=8.0)
        rng = np.random.default_rng(15)
        st_k = st_j = init(FleetConfig(ace=cfg, num_tenants=3))
        from repro.core import sketch as sk
        w = sk.make_params(cfg)
        for i in range(3):
            q = _x(24, 14, seed=3 + i)
            tids = jnp.asarray(rng.integers(0, 3, size=(24,)), jnp.int32)
            st_k, mask_k = ops.ace_fleet_admit(st_k, q, tids, w, cfg,
                                               alpha=1.0,
                                               warmup_items=16.0)
            buckets = hash_buckets(q, w, cfg.srp)
            scores = fleet_scores(st_j, tids, buckets)
            mask_j = scores >= admit_thresholds(st_j, 1.0, 16.0)[tids]
            st_j = insert_masked(st_j, tids, buckets, mask_j, cfg)
            assert bool(jnp.all(mask_k == mask_j))
        assert bool(jnp.all(st_k.counts == st_j.counts))
        assert bool(jnp.all(st_k.n == st_j.n))
        assert_allclose_dtype(st_k.welford_mean, st_j.welford_mean,
                              rtol=1e-6)
        assert_allclose_dtype(st_k.welford_m2, st_j.welford_m2,
                              rtol=1e-5)

    def test_ops_fleet_window_admit_matches_jnp_path(self):
        """ops.ace_fleet_window_admit (ONE fused launch + shared stats
        epilogue) ≡ the composed jnp fleet-window path over multiple
        rounds WITH rotation: masks/counts/cursor/tick bitwise, Welford
        streams to float tolerance."""
        from repro.core import sketch as sk
        from repro.core.srp import hash_buckets
        from repro.fleet import window as fw
        from repro.window import ring as rg
        cfg = AceConfig(dim=14, num_bits=6, num_tables=8, seed=9,
                        welford_min_n=8.0)
        wcfg = rg.WindowConfig(ace=cfg, num_epochs=3)
        w = sk.make_params(cfg)
        st_k = st_j = fw.init_fleet_window(wcfg, 3)
        rng = np.random.default_rng(21)
        for i in range(6):
            q = _x(16, 14, seed=30 + i)
            tids = jnp.asarray(rng.integers(0, 3, size=(16,)), jnp.int32)
            st_k, mask_k = ops.ace_fleet_window_admit(
                st_k, q, tids, w, cfg, gamma=0.7, alpha=1.0,
                warmup_items=12.0, rotate_every=2)
            thr = fw.window_admit_thresholds(st_j, 0.7, 1.0, 12.0)
            buckets = hash_buckets(q, w, cfg.srp)
            pre = fw.window_table_sums_fleet(st_j, tids, buckets)
            scores = rg.score_live(pre[0], pre[1], cfg.num_tables)
            mask_j = scores >= thr[tids]
            st_j = fw.insert_current_fleet(st_j, tids, buckets, mask_j,
                                           cfg, gamma=0.7, pre_sums=pre)
            st_j = fw.maybe_rotate_fleet(st_j, 2, 0.7, tenant_ids=tids)
            assert bool(jnp.all(mask_k == mask_j)), f"round {i}"
        assert bool(jnp.all(st_k.counts == st_j.counts))
        assert bool(jnp.all(st_k.cursor == st_j.cursor))
        assert bool(jnp.all(st_k.tick == st_j.tick))
        assert bool(jnp.all(st_k.n == st_j.n))
        assert_allclose_dtype(st_k.tail, st_j.tail, rtol=1e-6)
        assert_allclose_dtype(st_k.ssq, st_j.ssq, rtol=1e-6)
        assert_allclose_dtype(st_k.welford_mean, st_j.welford_mean,
                              rtol=1e-6)
        assert_allclose_dtype(st_k.welford_m2, st_j.welford_m2,
                              rtol=1e-5)

    def test_ops_fleet_window_admit_srht_exact(self):
        """SRHT dispatch: the srht hash kernel is bitwise the jnp hash,
        so the whole composed path must be EXACT vs the jnp helpers."""
        from repro.core import sketch as sk
        from repro.core.srp import hash_buckets
        from repro.fleet import window as fw
        from repro.window import ring as rg
        cfg = AceConfig(dim=16, num_bits=6, num_tables=8, seed=3,
                        hash_mode="srht")
        wcfg = rg.WindowConfig(ace=cfg, num_epochs=2)
        w = sk.make_params(cfg)
        st_k = st_j = fw.init_fleet_window(wcfg, 2)
        rng = np.random.default_rng(22)
        for i in range(2):
            q = _x(12, 16, seed=40 + i)
            tids = jnp.asarray(rng.integers(0, 2, size=(12,)), jnp.int32)
            st_k, mask_k = ops.ace_fleet_window_admit(
                st_k, q, tids, w, cfg, gamma=1.0, alpha=1.0,
                warmup_items=6.0)
            thr = fw.window_admit_thresholds(st_j, 1.0, 1.0, 6.0)
            buckets = hash_buckets(q, w, cfg.srp)
            pre = fw.window_table_sums_fleet(st_j, tids, buckets)
            scores = rg.score_live(pre[0], pre[1], cfg.num_tables)
            mask_j = scores >= thr[tids]
            st_j = fw.insert_current_fleet(st_j, tids, buckets, mask_j,
                                           cfg, gamma=1.0, pre_sums=pre)
            assert bool(jnp.all(mask_k == mask_j))
        for a, b in zip(jax.tree.leaves(st_k), jax.tree.leaves(st_j)):
            assert bool(jnp.array_equal(a, b))

    def test_ops_window_score_matches_ring_reference(self):
        """ops.ace_window_score (kernel path, cursor-derived weights)
        ≡ repro.window.score_windowed at matching γ."""
        from repro.window import ring
        from repro.core.sketch import AceConfig
        cfg = AceConfig(dim=10, num_bits=6, num_tables=8, seed=7)
        rng = np.random.default_rng(14)
        st = ring.init(cfg, 3)
        for _ in range(5):
            b = jnp.asarray(rng.integers(0, 64, size=(9, 8)), jnp.int32)
            st = ring.insert_current(st, b, jnp.ones((9,), bool), cfg)
            st = ring.maybe_rotate(st, 2, 0.6)
        q = jnp.asarray(rng.integers(0, 64, size=(12, 8)), jnp.int32)
        assert_allclose_dtype(ops.ace_window_score(st, q, 0.6),
                              ring.score_windowed(st, q, 0.6), rtol=1e-6)


class TestFleetWindowAdmitKernel:
    """What the parity matrix can't express: launch counts, narrow
    rings, pad rows, threshold routing."""

    def _setup(self, B=11, d=24, K=5, L=6, T=2, E=2, seed=0,
               ring_dtype=jnp.int32):
        cfg = SrpConfig(dim=d, num_bits=K, num_tables=L, seed=seed)
        w = make_projections(cfg)
        x = _x(B, d, seed=seed + 1)
        rng = np.random.default_rng(seed + 2)
        ring = jnp.asarray(rng.integers(0, 9, size=(T, E, L, 1 << K)),
                           ring_dtype)
        tail = jnp.asarray(rng.uniform(0, 3, size=(T, L, 1 << K)),
                           jnp.float32)
        cursor = jnp.asarray(rng.integers(0, E, size=(T,)), jnp.int32)
        tids = jnp.asarray(rng.integers(0, T, size=(B,)), jnp.int32)
        return cfg, w, x, ring, tail, cursor, tids

    def test_single_launch_and_no_retrace(self, monkeypatch):
        """THE fusion claim: one pallas_call per trace — and a repeat
        call at the same shape re-traces nothing at all."""
        from repro.kernels import ace_fleet_window_admit as fwa
        cfg, w, x, ring, tail, cursor, tids = self._setup(
            B=9, d=40, K=4, L=7, T=2, E=3, seed=77)   # fresh jit key
        thr = jnp.zeros((2,), jnp.float32)
        calls = []
        real = fwa.pl.pallas_call
        monkeypatch.setattr(
            fwa.pl, "pallas_call",
            lambda *a, **k: (calls.append(1), real(*a, **k))[1])
        out1 = fwa.ace_fleet_window_admit_fused(
            ring, tail, cursor, x, tids, w, thr, cfg, interpret=True)
        jax.block_until_ready(out1[0])
        assert len(calls) == 1, "fused admit must be ONE kernel launch"
        out2 = fwa.ace_fleet_window_admit_fused(
            ring, tail, cursor, x, tids, w, thr, cfg, interpret=True)
        jax.block_until_ready(out2[0])
        assert len(calls) == 1, "same-shape repeat call re-traced"

    @pytest.mark.parametrize("ring_dtype",
                             [jnp.int32, jnp.int16, jnp.int8])
    def test_narrow_ring_dtypes(self, ring_dtype):
        """Quantized rings pass straight through: the masked RMW adds in
        the ring's own dtype, exact below saturation, dtype preserved."""
        from repro.kernels.ace_fleet_window_admit import \
            ace_fleet_window_admit_fused
        cfg, w, x, ring, tail, cursor, tids = self._setup(
            ring_dtype=ring_dtype)
        thr = jnp.full((2,), -np.inf, jnp.float32)
        new_ring, scores, admit, buckets, *_ = \
            ace_fleet_window_admit_fused(ring, tail, cursor, x, tids, w,
                                         thr, cfg, interpret=True)
        assert new_ring.dtype == ring_dtype
        want = R.ace_fleet_window_admit_ref(
            ring, tail, cursor, x, tids, w, thr, cfg)[0]
        assert bool(jnp.all(new_ring == want))
        assert bool(jnp.all(admit))

    def test_threshold_extremes_route_per_tenant(self):
        """thr=[-inf, +inf]: tenant 0's items all admit, tenant 1's none
        — per-tenant routing, not a broadcast scalar."""
        from repro.kernels.ace_fleet_window_admit import \
            ace_fleet_window_admit_fused
        cfg, w, x, ring, tail, cursor, tids = self._setup()
        thr = jnp.asarray([-np.inf, np.inf], jnp.float32)
        new_ring, _s, admit, _b, *_ = ace_fleet_window_admit_fused(
            ring, tail, cursor, x, tids, w, thr, cfg, interpret=True)
        admit = np.asarray(admit)
        tids_np = np.asarray(tids)
        assert admit[tids_np == 0].all()
        assert not admit[tids_np == 1].any()
        inserted = int((np.asarray(new_ring) - np.asarray(ring)).sum())
        assert inserted == int((tids_np == 0).sum()) * cfg.num_tables

    def test_pad_rows_never_insert(self):
        """B=5 (pad to 8): garbage pad rows must not scatter."""
        from repro.kernels.ace_fleet_window_admit import \
            ace_fleet_window_admit_fused
        cfg, w, x, ring, tail, cursor, tids = self._setup(B=5)
        thr = jnp.full((2,), -np.inf, jnp.float32)
        new_ring, scores, admit, _b, *_ = ace_fleet_window_admit_fused(
            ring, tail, cursor, x, tids, w, thr, cfg, interpret=True)
        assert admit.shape == (5,) and scores.shape == (5,)
        inserted = int((np.asarray(new_ring) - np.asarray(ring)).sum())
        assert inserted == 5 * cfg.num_tables

    def test_vmem_budget_guard(self):
        """A ring past the ~14 MB VMEM budget raises on the non-interpret
        path instead of failing inside Mosaic."""
        from repro.kernels.ace_fleet_window_admit import \
            ace_fleet_window_admit_fused
        cfg = SrpConfig(dim=8, num_bits=13, num_tables=50, seed=0)
        w = make_projections(cfg)
        x = _x(4, 8)
        T, E, L, nb = 4, 4, 50, 1 << 13
        ring = jnp.zeros((T, E, L, nb), jnp.int32)
        tail = jnp.zeros((T, L, nb), jnp.float32)
        with pytest.raises(ValueError, match="VMEM"):
            ace_fleet_window_admit_fused(
                ring, tail, jnp.zeros((T,), jnp.int32), x,
                jnp.zeros((4,), jnp.int32), w, jnp.zeros((T,)), cfg,
                interpret=False)


class TestQuantizedCountRows:
    """Quantized-dtype parity rows: the scoring kernels gather narrow
    planes exactly (upcast in the gather, f32 downstream ≡ int32 rows)."""

    @pytest.mark.parametrize("dtype", [jnp.int32, jnp.int16, jnp.int8])
    def test_score_fused_dtypes(self, dtype):
        cfg = SrpConfig(dim=20, num_bits=7, num_tables=9, seed=5)
        w = make_projections(cfg)
        x = _x(26, 20, seed=6)
        rng = np.random.default_rng(7)
        counts = jnp.asarray(rng.integers(0, 9, size=(9, 128)), dtype)
        got = ace_score_fused(counts, x, w, cfg, interpret=True)
        want = R.ace_score_ref(counts.astype(jnp.int32), x, w, cfg)
        assert_allclose_dtype(got, want, rtol=1e-6)

    @pytest.mark.parametrize("dtype", [jnp.int32, jnp.int16, jnp.int8])
    def test_fleet_score_dtypes(self, dtype):
        cfg = SrpConfig(dim=20, num_bits=7, num_tables=9, seed=5)
        w = make_projections(cfg)
        x = _x(26, 20, seed=6)
        rng = np.random.default_rng(8)
        counts = jnp.asarray(rng.integers(0, 9, size=(3, 9, 128)), dtype)
        tids = jnp.asarray(rng.integers(0, 3, size=(26,)), jnp.int32)
        got = ace_fleet_score(counts, x, tids, w, cfg, interpret=True)
        want = R.ace_fleet_score_ref(counts.astype(jnp.int32), x, tids,
                                     w, cfg)
        assert_allclose_dtype(got, want, rtol=1e-6)

    @pytest.mark.parametrize("dtype", [jnp.int32, jnp.int16, jnp.int8])
    def test_window_combine_dtypes(self, dtype):
        rng = np.random.default_rng(9)
        counts = jnp.asarray(rng.integers(0, 9, size=(3, 6, 64)), dtype)
        buckets = jnp.asarray(rng.integers(0, 64, size=(22, 6)),
                              jnp.int32)
        weights = jnp.asarray([1.0, 0.6, 0.36], jnp.float32)
        got = ace_window_combine(counts, buckets, weights,
                                 interpret=True)
        want = R.ace_window_combine_ref(counts.astype(jnp.int32),
                                        buckets, weights)
        assert_allclose_dtype(got, want, rtol=1e-6)


class TestAutotunerCache:
    """runtime.autotune cache keying: per (kernel, shape, backend), the
    'interpret' pseudo-backend NEVER shares entries with a real one, and
    a backend-probe change invalidates everything."""

    @pytest.fixture(autouse=True)
    def _clean_cache(self):
        from repro.kernels import runtime as rt
        saved_cache = dict(rt._AUTOTUNE_CACHE)
        saved_probe = rt._PROBED_BACKEND
        rt._AUTOTUNE_CACHE.clear()
        rt._PROBED_BACKEND = None
        yield
        rt._AUTOTUNE_CACHE.clear()
        rt._AUTOTUNE_CACHE.update(saved_cache)
        rt._PROBED_BACKEND = saved_probe

    def test_interpret_run_never_poisons_backend_key(self, monkeypatch):
        """THE regression this cache keying exists for: an interpret-mode
        warmup tunes some CPU-friendly tile; a later TPU-backend call at
        the same shape must NOT inherit it."""
        from repro.kernels import runtime as rt
        shape = ((64, 128), (128, 256))
        cpu_winner = rt.autotune(
            "srp_hash", shape, True, [(128, 512), (256, 512)],
            bench_fn=lambda cand: jnp.zeros(2))
        assert ("srp_hash", shape, "interpret") in rt._AUTOTUNE_CACHE
        # now the process discovers a TPU (probe change) and asks again
        monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
        got = rt.autotune("srp_hash", shape, False,
                          [(512, 512), (256, 512)], bench_fn=None)
        # bench_fn=None (can't time) -> first candidate of the NEW list,
        # NOT the interpret-tuned winner
        assert got == (512, 512) and got != cpu_winner
        assert ("srp_hash", shape, "tpu") not in rt._AUTOTUNE_CACHE

    def test_backend_probe_change_clears_cache(self, monkeypatch):
        from repro.kernels import runtime as rt
        rt.autotune("k", (1,), True, [(8,)],
                    bench_fn=lambda c: jnp.zeros(1))
        assert rt._AUTOTUNE_CACHE
        monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
        rt._check_backend_probe()
        assert not rt._AUTOTUNE_CACHE

    def test_winner_is_cached_per_shape(self):
        from repro.kernels import runtime as rt
        calls = []

        def bench(cand):
            calls.append(cand)
            return jnp.zeros(1)

        a = rt.autotune("k", (8,), True, [(1,), (2,)], bench_fn=bench)
        n = len(calls)
        b = rt.autotune("k", (8,), True, [(1,), (2,)], bench_fn=bench)
        assert a == b and len(calls) == n, "second call must hit cache"
        rt.autotune("k", (16,), True, [(1,), (2,)], bench_fn=bench)
        assert len(calls) > n, "different shape must re-tune"

    def test_degraded_call_does_not_cache(self):
        from repro.kernels import runtime as rt
        got = rt.autotune("k", (8,), True, [(3,), (4,)], bench_fn=None)
        assert got == (3,)
        assert not rt._AUTOTUNE_CACHE, \
            "bench-less call must not pin the default"

    def test_all_failing_candidates_fall_back_uncached(self):
        from repro.kernels import runtime as rt

        def bench(cand):
            raise RuntimeError("no lowering")

        got = rt.autotune("k", (8,), True, [(5,), (6,)], bench_fn=bench)
        assert got == (5,) and not rt._AUTOTUNE_CACHE

    def test_srp_hash_auto_tiles_match_fixed(self):
        """bm/bk='auto' end to end: same buckets as the default tiling,
        and the winner lands in the cache under the interpret key."""
        from repro.kernels import runtime as rt
        cfg = SrpConfig(dim=48, num_bits=5, num_tables=6, seed=11)
        w = make_projections(cfg)
        x = _x(19, 48, seed=12)
        got = srp_hash(x, w, cfg, bm="auto", bk="auto", interpret=True)
        assert bool(jnp.array_equal(got, R.srp_hash_ref(x, w, cfg)))
        assert any(k[0] == "srp_hash" and k[2] == "interpret"
                   for k in rt._AUTOTUNE_CACHE)

    def test_srp_hash_auto_under_trace_falls_back(self):
        """jit-traced operands can't be timed: 'auto' must neither crash
        nor cache, and still hash correctly."""
        from repro.kernels import runtime as rt
        cfg = SrpConfig(dim=32, num_bits=4, num_tables=5, seed=13)
        w = make_projections(cfg)
        x = _x(9, 32, seed=14)
        f = jax.jit(lambda x_: srp_hash(x_, w, cfg, bm="auto", bk="auto",
                                        interpret=True))
        got = f(x)
        assert bool(jnp.array_equal(got, R.srp_hash_ref(x, w, cfg)))
        assert not any(k[0] == "srp_hash" for k in rt._AUTOTUNE_CACHE)
