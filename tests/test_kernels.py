"""Per-kernel validation: shape/dtype sweeps, hypothesis property tests,
assert_allclose against the pure-jnp oracles in repro.kernels.ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sketch import AceConfig
from repro.core.srp import SrpConfig, hash_buckets, make_projections
from repro.kernels import ref as R
from repro.kernels import ops
from repro.kernels.ace_query import ace_query
from repro.kernels.ace_score_fused import ace_score_fused
from repro.kernels.ace_update import ace_update
from repro.kernels.srp_hash import srp_hash

jax.config.update("jax_platform_name", "cpu")


def _x(B, d, seed=0, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(B, d)), dtype)


SHAPES = [
    # (B, d, K, L) — deliberately awkward: non-multiples of 8/128, L>B, tiny.
    (16, 32, 8, 10),
    (100, 300, 15, 50),   # paper's K, L
    (7, 9, 4, 3),
    (1, 257, 10, 20),
    (33, 128, 12, 50),
    (256, 64, 6, 7),
]


class TestSrpHashKernel:
    @pytest.mark.parametrize("B,d,K,L", SHAPES)
    def test_matches_ref(self, B, d, K, L):
        cfg = SrpConfig(dim=d, num_bits=K, num_tables=L, seed=B + d)
        w = make_projections(cfg)
        x = _x(B, d, seed=d)
        got = srp_hash(x, w, cfg)
        want = R.srp_hash_ref(x, w, cfg)
        assert got.shape == (B, L) and got.dtype == jnp.int32
        assert bool(jnp.all(got == want))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        cfg = SrpConfig(dim=64, num_bits=8, num_tables=10, seed=0)
        w = make_projections(cfg, dtype=dtype)
        x = _x(40, 64, dtype=dtype)
        got = srp_hash(x, w, cfg)
        want = R.srp_hash_ref(x, w, cfg)
        # bf16 sign flips only where |proj| underflows; require > 99% agree
        agree = float(jnp.mean((got == want).astype(jnp.float32)))
        assert agree > 0.99

    @settings(max_examples=15, deadline=None)
    @given(B=st.integers(1, 70), d=st.integers(2, 200),
           K=st.integers(1, 15), L=st.integers(1, 50))
    def test_property_sweep(self, B, d, K, L):
        cfg = SrpConfig(dim=d, num_bits=K, num_tables=L, seed=1)
        w = make_projections(cfg)
        x = _x(B, d, seed=B * d % 97)
        assert bool(jnp.all(srp_hash(x, w, cfg) == R.srp_hash_ref(x, w, cfg)))

    @pytest.mark.parametrize("bm,bk", [(8, 128), (64, 256), (256, 512)])
    def test_block_shape_invariance(self, bm, bk):
        """Result must not depend on the tiling choice."""
        cfg = SrpConfig(dim=200, num_bits=10, num_tables=30, seed=2)
        w = make_projections(cfg)
        x = _x(90, 200)
        assert bool(jnp.all(srp_hash(x, w, cfg, bm=bm, bk=bk) ==
                            R.srp_hash_ref(x, w, cfg)))


class TestAceUpdateKernel:
    @pytest.mark.parametrize("B,d,K,L", SHAPES)
    def test_matches_ref(self, B, d, K, L):
        rng = np.random.default_rng(B)
        counts = jnp.asarray(rng.integers(0, 7, size=(L, 1 << K)), jnp.int32)
        buckets = jnp.asarray(rng.integers(0, 1 << K, size=(B, L)), jnp.int32)
        got = ace_update(counts, buckets)
        want = R.ace_update_ref(counts, buckets)
        assert bool(jnp.all(got == want))

    def test_duplicate_buckets_accumulate(self):
        """Collision-safety: many items in one bucket must all count."""
        L, K, B = 4, 6, 32
        counts = jnp.zeros((L, 1 << K), jnp.int32)
        buckets = jnp.full((B, L), 5, jnp.int32)
        got = ace_update(counts, buckets)
        assert int(got[0, 5]) == B and int(got.sum()) == B * L

    @pytest.mark.parametrize("dtype", [jnp.int32, jnp.int16])
    def test_counter_dtypes(self, dtype):
        rng = np.random.default_rng(3)
        counts = jnp.zeros((8, 256), dtype)
        buckets = jnp.asarray(rng.integers(0, 256, size=(50, 8)), jnp.int32)
        got = ace_update(counts, buckets)
        want = R.ace_update_ref(counts, buckets)
        assert got.dtype == dtype and bool(jnp.all(got == want))


class TestAceQueryKernel:
    @pytest.mark.parametrize("B,d,K,L", SHAPES)
    @pytest.mark.parametrize("mode", ["vector", "scalar"])
    def test_matches_ref(self, B, d, K, L, mode):
        rng = np.random.default_rng(B + 1)
        counts = jnp.asarray(rng.integers(0, 9, size=(L, 1 << K)), jnp.int32)
        buckets = jnp.asarray(rng.integers(0, 1 << K, size=(B, L)), jnp.int32)
        got = ace_query(counts, buckets, mode=mode)
        want = R.ace_query_ref(counts, buckets)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))

    def test_batch_tiling_invariance(self):
        rng = np.random.default_rng(5)
        counts = jnp.asarray(rng.integers(0, 9, size=(10, 256)), jnp.int32)
        buckets = jnp.asarray(rng.integers(0, 256, size=(130, 10)), jnp.int32)
        a = ace_query(counts, buckets, bm=32)
        b = ace_query(counts, buckets, bm=1024)
        assert bool(jnp.all(a == b))


class TestFusedScoreKernel:
    @pytest.mark.parametrize("B,d,K,L", SHAPES)
    def test_matches_ref(self, B, d, K, L):
        cfg = SrpConfig(dim=d, num_bits=K, num_tables=L, seed=B)
        w = make_projections(cfg)
        x = _x(B, d, seed=7)
        rng = np.random.default_rng(9)
        counts = jnp.asarray(rng.integers(0, 9, size=(L, 1 << K)), jnp.int32)
        got = ace_score_fused(counts, x, w, cfg)
        want = R.ace_score_ref(counts, x, w, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6)

    def test_fused_equals_two_kernel_path(self):
        cfg = SrpConfig(dim=100, num_bits=10, num_tables=25, seed=4)
        w = make_projections(cfg)
        x = _x(77, 100)
        rng = np.random.default_rng(11)
        counts = jnp.asarray(rng.integers(0, 9, size=(25, 1024)), jnp.int32)
        fused = ace_score_fused(counts, x, w, cfg)
        two = jnp.mean(ace_query(counts, srp_hash(x, w, cfg)), axis=-1)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(two),
                                   rtol=1e-6)


class TestOpsDispatch:
    def test_ops_roundtrip_matches_sketch(self):
        """Kernel-path insert+score equals the pure-jnp sketch path."""
        from repro.core import sketch as sk
        cfg = AceConfig(dim=20, num_bits=8, num_tables=12, seed=6)
        w = sk.make_params(cfg)
        x = _x(64, 20)
        st_k = ops.ace_update(sk.init(cfg),
                              ops.srp_hash(x, w, cfg.srp), cfg)
        st_j = sk.insert(sk.init(cfg), w, x, cfg)
        assert bool(jnp.all(st_k.counts == st_j.counts))
        q = _x(16, 20, seed=1)
        np.testing.assert_allclose(
            np.asarray(ops.ace_score(st_k, q, w, cfg)),
            np.asarray(sk.score(st_j, w, q, cfg)), rtol=1e-6)
