"""Table-sharded sketch parity: the repro.dist.sketch_parallel
table-sharded layout must agree EXACTLY (counts, scores, μ, Welford σ
stream) with the single-device replicated path — every cross-shard
reduction sums exactly-representable integers in float32, so the match is
bitwise, not approximate.  Runs on a 1×2 CPU mesh of fake devices via
subprocess (the main test process must keep seeing 1 device — see
launch/dryrun.py's contract)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# every test here round-trips a subprocess with a forced multi-device CPU
# topology — minutes, not seconds; the CI fast lane (-m "not slow") skips them
pytestmark = pytest.mark.slow


def run_py(code: str, devices: int = 2, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


class TestTableShardedParity:
    def test_insert_score_mu_bitwise_match_replicated(self):
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import sketch as sk
            from repro.core.sketch import AceConfig
            from repro.dist.sketch_parallel import (
                make_table_sharded_mean_mu, make_table_sharded_score,
                make_table_sharded_update, table_sharded_shardings)

            cfg = AceConfig(dim=8, num_bits=6, num_tables=10, seed=0)
            mesh = jax.make_mesh((1, 2), ("data", "model"))
            w = sk.make_params(cfg)
            rng = np.random.default_rng(0)
            x = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
            q = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)

            ref = sk.insert(sk.init(cfg), w, x, cfg)
            ref_scores = sk.score(ref, w, q, cfg)

            upd = make_table_sharded_update(mesh, cfg)
            scr = make_table_sharded_score(mesh, cfg)
            mu_fn = make_table_sharded_mean_mu(mesh, cfg)
            with jax.set_mesh(mesh):
                state = jax.device_put(sk.init(cfg),
                                       table_sharded_shardings(mesh))
                out = upd(state, x, w)
                scores = scr(out, q, w)
                mu = mu_fn(out)

            assert bool(jnp.all(jnp.asarray(out.counts)
                                == ref.counts)), "counts differ"
            assert bool(jnp.all(jnp.asarray(scores)
                                == ref_scores)), "scores differ"
            assert float(mu) == float(sk.mean_mu(ref)), "mu differs"
            assert float(out.n) == float(ref.n)
            # the Welford scalars are reassociation-sensitive (fast-math);
            # the contract there is tight-tolerance, not bitwise
            np.testing.assert_allclose(float(out.welford_mean),
                                       float(ref.welford_mean), rtol=1e-6)
            np.testing.assert_allclose(float(out.welford_m2),
                                       float(ref.welford_m2), rtol=1e-6)
            print("PARITY_OK", float(mu))
        """)
        assert "PARITY_OK" in out

    def test_second_insert_batch_keeps_parity(self):
        """The Welford stream stays bitwise-equal across multiple batches
        (n > 0 path of the cold-start gate)."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import sketch as sk
            from repro.core.sketch import AceConfig
            from repro.dist.sketch_parallel import (
                make_table_sharded_update, table_sharded_shardings)

            cfg = AceConfig(dim=8, num_bits=5, num_tables=8, seed=1,
                            welford_min_n=16.0)
            mesh = jax.make_mesh((1, 2), ("data", "model"))
            w = sk.make_params(cfg)
            rng = np.random.default_rng(1)
            xs = [jnp.asarray(rng.normal(size=(48, 8)), jnp.float32)
                  for _ in range(3)]

            ref = sk.init(cfg)
            for x in xs:
                ref = sk.insert(ref, w, x, cfg)

            upd = make_table_sharded_update(mesh, cfg)
            with jax.set_mesh(mesh):
                st = jax.device_put(sk.init(cfg),
                                    table_sharded_shardings(mesh))
                for x in xs:
                    st = upd(st, x, w)
            assert bool(jnp.all(jnp.asarray(st.counts) == ref.counts))
            np.testing.assert_allclose(float(st.welford_mean),
                                       float(ref.welford_mean), rtol=1e-6)
            np.testing.assert_allclose(float(st.welford_m2),
                                       float(ref.welford_m2), rtol=1e-6)
            np.testing.assert_allclose(float(sk.sigma_welford(st)),
                                       float(sk.sigma_welford(ref)),
                                       rtol=1e-6)
            print("STREAM_OK")
        """)
        assert "STREAM_OK" in out

    def test_merge_exact_across_layouts(self):
        """merge (the CRDT count addition + Chan Welford rule) gives the
        same sketch whether its inputs are replicated or table-sharded."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import sketch as sk
            from repro.core.sketch import AceConfig
            from repro.dist.sketch_parallel import (
                make_table_sharded_update, table_sharded_shardings)

            cfg = AceConfig(dim=6, num_bits=5, num_tables=6, seed=2)
            mesh = jax.make_mesh((1, 2), ("data", "model"))
            w = sk.make_params(cfg)
            rng = np.random.default_rng(2)
            xa = jnp.asarray(rng.normal(size=(40, 6)), jnp.float32)
            xb = jnp.asarray(rng.normal(size=(24, 6)), jnp.float32)

            ra = sk.insert(sk.init(cfg), w, xa, cfg)
            rb = sk.insert(sk.init(cfg), w, xb, cfg)
            ref = sk.merge(ra, rb)

            upd = make_table_sharded_update(mesh, cfg)
            with jax.set_mesh(mesh):
                sh = table_sharded_shardings(mesh)
                sa = upd(jax.device_put(sk.init(cfg), sh), xa, w)
                sb = upd(jax.device_put(sk.init(cfg), sh), xb, w)
                merged = jax.jit(sk.merge)(sa, sb)
            assert bool(jnp.all(jnp.asarray(merged.counts) == ref.counts))
            assert float(merged.n) == float(ref.n)
            np.testing.assert_allclose(float(merged.welford_mean),
                                       float(ref.welford_mean), rtol=1e-6)
            np.testing.assert_allclose(float(merged.welford_m2),
                                       float(ref.welford_m2), rtol=1e-6)
            assert float(sk.mean_mu(merged)) == float(sk.mean_mu(ref))
            print("MERGE_OK")
        """)
        assert "MERGE_OK" in out

    def test_spmd_mode_placement_stays_exact(self):
        """jit/SPMD mode: plain repro.core.sketch ops on a table-sharded
        placement produce the replicated results (GSPMD inserts the
        collectives)."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import sketch as sk
            from repro.core.sketch import AceConfig
            from repro.dist.sketch_parallel import table_sharded_shardings

            cfg = AceConfig(dim=8, num_bits=6, num_tables=10, seed=0)
            mesh = jax.make_mesh((1, 2), ("data", "model"))
            w = sk.make_params(cfg)
            x = jnp.asarray(
                np.random.default_rng(0).normal(size=(64, 8)), jnp.float32)
            ref = sk.insert(sk.init(cfg), w, x, cfg)
            with jax.set_mesh(mesh):
                st = jax.device_put(sk.init(cfg),
                                    table_sharded_shardings(mesh))
                out = sk.insert(st, w, x, cfg)
                scores = sk.score(out, w, x, cfg)
            assert bool(jnp.all(jnp.asarray(out.counts) == ref.counts))
            ref_scores = sk.score(ref, w, x, cfg)
            np.testing.assert_allclose(np.asarray(scores),
                                       np.asarray(ref_scores), rtol=1e-6)
            print("SPMD_OK")
        """)
        assert "SPMD_OK" in out


class TestTrainStepSketchLayout:
    def test_table_sharded_monitor_in_train_step(self):
        """make_train_step(sketch_layout="table_sharded") compiles and runs:
        the ACE data-filter and grad-monitor sketch states are constrained
        over the tables axis inside the step (jit/SPMD mode)."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.models.common import set_rules
            from repro.models.registry import Arch
            from repro.train.train_loop import (TrainConfig,
                                                init_train_state,
                                                make_train_step)
            mesh = jax.make_mesh((1, 2), ("data", "model"))
            set_rules({"batch": ("data",), "heads": "model",
                       "kv_heads": "model", "ff": "model",
                       "vocab": "model"})
            a = Arch("olmo_1b", reduced=True)
            tcfg = TrainConfig(use_data_filter=True, use_grad_monitor=True,
                               warmup_steps=1, peak_lr=1e-3)
            with jax.set_mesh(mesh):
                state = init_train_state(a, tcfg, jax.random.PRNGKey(0))
                step = jax.jit(make_train_step(
                    a, tcfg, sketch_layout="table_sharded"))
                rng = np.random.default_rng(0)
                batch = {"tokens": jnp.asarray(
                             rng.integers(0, 512, (4, 16)), jnp.int32),
                         "labels": jnp.asarray(
                             rng.integers(0, 512, (4, 16)), jnp.int32)}
                for _ in range(2):
                    state, metrics = step(state, batch)
            assert np.isfinite(float(metrics["loss"]))
            assert float(state.monitor.ace.n) > 0   # monitor inserted
            print("LAYOUT_TRAIN_OK", float(metrics["loss"]))
        """)
        assert "LAYOUT_TRAIN_OK" in out


class TestValidation:
    def test_indivisible_tables_raise(self):
        """L must divide over the tables axis — no silent padding."""
        out = run_py("""
            import jax
            from repro.core.sketch import AceConfig
            from repro.dist.sketch_parallel import make_table_sharded_update

            cfg = AceConfig(dim=4, num_bits=4, num_tables=9, seed=0)
            mesh = jax.make_mesh((1, 2), ("data", "model"))
            try:
                make_table_sharded_update(mesh, cfg)
            except ValueError as e:
                assert "9" in str(e)
                print("RAISED_OK")
        """)
        assert "RAISED_OK" in out

    def test_missing_axis_raises(self):
        out = run_py("""
            import jax
            from repro.core.sketch import AceConfig
            from repro.dist.sketch_parallel import make_table_sharded_score

            cfg = AceConfig(dim=4, num_bits=4, num_tables=8, seed=0)
            mesh = jax.make_mesh((2,), ("data",))
            try:
                make_table_sharded_score(mesh, cfg, table_axis="tables")
            except ValueError as e:
                assert "tables" in str(e)
                print("RAISED_OK")
        """)
        assert "RAISED_OK" in out
