"""Differential-oracle suite for ``repro.fleet``.

Every fleet op is validated against the single-tenant code it stacks:

* **fleet-of-1** — T=1 with all-zero tenant ids must be BITWISE the
  plain ``AceDataFilter`` / ``repro.core.sketch`` path (and the
  windowed fleet-of-1 bitwise the ``repro.window`` ring).
* **mixed batch ≡ per-tenant sequential** — routing one mixed batch
  equals giving each tenant the full fixed-shape batch with its own
  sub-mask through ``sketch.insert_buckets_masked`` (bitwise on counts,
  n, μ AND the Welford moments — the per-tenant segment reductions sum
  value sequences whose masked-out entries are exact float zeros).
* **tenant isolation** — hypothesis property: traffic routed to one
  tenant leaves every other tenant's state bitwise untouched, flat and
  windowed (incl. per-tenant rotation clocks).
* **sharded parity** — the tenant-sharded and composed
  tenant×table-sharded jit/SPMD placements reproduce the single-device
  results bitwise on a fake multi-device CPU mesh (subprocess; slow).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sketch as sk
from repro.core.sketch import AceConfig
from repro.data.pipeline import AceDataFilter
from repro.fleet import (FleetConfig, FleetDataFilter, admit_thresholds,
                         fleet_scores, init as fleet_init, insert_masked,
                         mean_mu_fleet, tenant_view)
from repro.fleet import window as fw
from repro.window import ring

jax.config.update("jax_platform_name", "cpu")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _buckets(rng, B, K, L):
    return jnp.asarray(rng.integers(0, 1 << K, size=(B, L)), jnp.int32)


CFG = AceConfig(dim=16, num_bits=7, num_tables=6, seed=3,
                welford_min_n=4.0)

# Leaves of a WindowedAceState that are exact integers in every context
# (counters, item counts, ring pointers).  The γ-decayed float caches
# (tail, ssq, Welford) are ALSO bitwise across contexts since the
# rotation recompute rewrite (see ring.rotate) — callers pass
# exact_floats=True to pin that; the tolerance lane remains for tests
# comparing genuinely different float paths.
_WINDOW_INT_LEAVES = ("counts", "n", "cursor", "tick")


def _assert_window_match(got, want, exact_floats: bool):
    from conftest import assert_allclose_dtype
    for f in ring.WindowedAceState._fields:
        ga, wa = getattr(got, f), getattr(want, f)
        if ga is None or wa is None:       # optional leaves (qhist)
            assert ga is None and wa is None, f
            continue
        a, b = np.asarray(ga), np.asarray(wa)
        if exact_floats or f in _WINDOW_INT_LEAVES:
            np.testing.assert_array_equal(a, b, err_msg=f)
        else:
            assert_allclose_dtype(a, b, err_msg=f)


def _filled_fleet(rng, T, steps=4, B=23, cfg=CFG):
    """A fleet + the per-tenant sequential oracle states, co-evolved."""
    fs = fleet_init(FleetConfig(ace=cfg, num_tenants=T))
    singles = [sk.init(cfg) for _ in range(T)]
    for _ in range(steps):
        buckets = _buckets(rng, B, cfg.num_bits, cfg.num_tables)
        tids = jnp.asarray(rng.integers(0, T, size=(B,)), jnp.int32)
        mask = jnp.asarray(rng.random(B) < 0.7)
        fs = insert_masked(fs, tids, buckets, mask, cfg)
        for t in range(T):
            singles[t] = sk.insert_buckets_masked(
                singles[t], buckets, jnp.logical_and(mask, tids == t), cfg)
    return fs, singles


class TestFleetOfOne:
    def test_filter_bitwise_equals_single_tenant(self):
        """FleetDataFilter(num_tenants=1) ≡ AceDataFilter, bitwise:
        same keep/margin per step, same final counts/n/Welford."""
        rng = np.random.default_rng(0)
        d = 24
        f1 = AceDataFilter(d_model=d, num_bits=6, num_tables=8,
                           warmup_items=16.0, alpha=2.0)
        ff = FleetDataFilter(d_model=d, num_tenants=1, num_bits=6,
                             num_tables=8, warmup_items=16.0, alpha=2.0)
        s1, w = f1.init()
        sf, wf = ff.init()
        assert bool(jnp.all(w == wf))
        tids = jnp.zeros((10,), jnp.int32)
        for i in range(6):
            feat = jnp.asarray(rng.normal(size=(10, d + 1)), jnp.float32)
            s1, k1, m1 = f1.step(s1, w, feat)
            sf, k2, m2 = ff.step(sf, w, feat, tids)
            assert bool(jnp.all(k1 == k2)), i
            assert bool(jnp.all(m1 == m2)), i
        assert bool(jnp.all(s1.counts == sf.counts[0]))
        assert float(s1.n) == float(sf.n[0])
        assert float(s1.welford_mean) == float(sf.welford_mean[0])
        assert float(s1.welford_m2) == float(sf.welford_m2[0])

    @pytest.mark.parametrize("gamma", [1.0, 0.8])
    def test_windowed_fleet_of_one_bitwise(self, gamma):
        """T=1 windowed fleet ≡ the plain epoch ring, rotation clock
        included — EVERY leaf bitwise at EVERY γ.  The γ<1 float caches
        (tail, ssq) used to be compared at dtype tolerance because the
        old incremental rotation fold FMA-drifted across trace contexts;
        the tensordot/einsum recompute in ring.rotate / rotate_fleet
        lowers identically everywhere, so the pin is gone and this test
        guards the stronger contract."""
        rng = np.random.default_rng(1)
        wc = ring.WindowConfig(ace=CFG, num_epochs=3, decay=gamma,
                               rotate_every=2)
        fs = fw.init_fleet_window(wc, 1)
        one = ring.init_window(wc)
        tids = jnp.zeros((15,), jnp.int32)
        for _ in range(7):
            buckets = _buckets(rng, 15, CFG.num_bits, CFG.num_tables)
            mask = jnp.asarray(rng.random(15) < 0.8)
            fs = fw.insert_current_fleet(fs, tids, buckets, mask, CFG,
                                         gamma=gamma)
            fs = fw.maybe_rotate_fleet(fs, 2, gamma, tenant_ids=tids)
            one = ring.insert_current(one, buckets, mask, CFG,
                                      gamma=gamma)
            one = ring.maybe_rotate(one, 2, gamma)
        _assert_window_match(fw.tenant_window_view(fs, 0), one,
                             exact_floats=True)


class TestMixedBatchVsSequential:
    def test_flat_insert_bitwise(self):
        """One mixed-batch ``insert_masked`` ≡ per-tenant sequential
        ``sketch.insert_buckets_masked`` — bitwise counts/n/μ/M2."""
        rng = np.random.default_rng(2)
        T = 5
        fs, singles = _filled_fleet(rng, T)
        mus = mean_mu_fleet(fs)
        for t in range(T):
            tv = tenant_view(fs, t)
            assert bool(jnp.all(tv.counts == singles[t].counts)), t
            assert float(tv.n) == float(singles[t].n), t
            assert float(tv.welford_mean) == \
                float(singles[t].welford_mean), t
            assert float(tv.welford_m2) == float(singles[t].welford_m2), t
            assert float(mus[t]) == float(sk.mean_mu(singles[t])), t

    def test_thresholds_route_each_tenants_own(self):
        """admit_thresholds[t] ≡ sketch.admit_threshold(tenant t) bitwise,
        including per-tenant warmup (−inf only for cold tenants)."""
        rng = np.random.default_rng(3)
        T = 4
        fs, singles = _filled_fleet(rng, T, steps=2, B=11)
        # starve tenant 0 completely: re-zero its slot
        from repro.fleet import set_tenant
        fs = set_tenant(fs, 0, sk.init(CFG))
        singles[0] = sk.init(CFG)
        th = admit_thresholds(fs, 2.0, 8.0)
        for t in range(T):
            assert float(th[t]) == \
                float(sk.admit_threshold(singles[t], 2.0, 8.0)), t
        assert float(th[0]) == -np.inf          # cold tenant still warming

    def test_scores_match_per_tenant_lookup(self):
        """fleet_scores ≡ sketch.lookup against each item's own tenant."""
        rng = np.random.default_rng(4)
        T = 5
        fs, singles = _filled_fleet(rng, T)
        B = 19
        buckets = _buckets(rng, B, CFG.num_bits, CFG.num_tables)
        tids = jnp.asarray(rng.integers(0, T, size=(B,)), jnp.int32)
        got = fleet_scores(fs, tids, buckets)
        for i in range(B):
            want = sk.lookup(singles[int(tids[i])], buckets[i:i + 1])
            assert float(got[i]) == float(want[0]), i

    @pytest.mark.parametrize("gamma", [1.0, 0.7])
    def test_windowed_mixed_vs_sequential_bitwise(self, gamma):
        """Windowed fleet: mixed-batch inserts + per-tenant clocks ≡
        per-tenant sequential ring ops — EVERY leaf bitwise at EVERY γ
        (cursor/tick included: a tenant's clock only ticks on batches
        that carried its items).  γ<1 float caches were tolerance-only
        before the rotation recompute rewrite (see the fleet-of-one
        test); they are bitwise now and pinned so."""
        rng = np.random.default_rng(5)
        T = 4
        wc = ring.WindowConfig(ace=CFG, num_epochs=3, decay=gamma,
                               rotate_every=2)
        fs = fw.init_fleet_window(wc, T)
        singles = [ring.init_window(wc) for _ in range(T)]
        for _ in range(9):
            B = 17
            buckets = _buckets(rng, B, CFG.num_bits, CFG.num_tables)
            tids = jnp.asarray(rng.integers(0, T, size=(B,)), jnp.int32)
            mask = jnp.asarray(rng.random(B) < 0.8)
            fs = fw.insert_current_fleet(fs, tids, buckets, mask, CFG,
                                         gamma=gamma)
            fs = fw.maybe_rotate_fleet(fs, 2, gamma, tenant_ids=tids)
            for t in range(T):
                if bool(jnp.any(tids == t)):    # absent tenants: no tick
                    singles[t] = ring.insert_current(
                        singles[t], buckets,
                        jnp.logical_and(mask, tids == t), CFG, gamma=gamma)
                    singles[t] = ring.maybe_rotate(singles[t], 2, gamma)
        for t in range(T):
            _assert_window_match(fw.tenant_window_view(fs, t),
                                 singles[t],
                                 exact_floats=True)


class TestTenantIsolation:
    @settings(max_examples=15, deadline=None)
    @given(T=st.integers(2, 7), B=st.integers(1, 40), seed=st.integers(0, 99))
    def test_insert_leaves_other_tenants_bitwise_unchanged(self, T, B,
                                                           seed):
        """Hypothesis property: inserting a batch routed entirely to
        tenant ``a`` leaves every other tenant's counts AND moments
        bitwise unchanged."""
        rng = np.random.default_rng(seed)
        fs, _ = _filled_fleet(rng, T, steps=2, B=13)
        a = int(rng.integers(0, T))
        buckets = _buckets(rng, B, CFG.num_bits, CFG.num_tables)
        tids = jnp.full((B,), a, jnp.int32)
        mask = jnp.asarray(rng.random(B) < 0.9)
        fs2 = insert_masked(fs, tids, buckets, mask, CFG)
        for t in range(T):
            if t == a:
                continue
            before, after = tenant_view(fs, t), tenant_view(fs2, t)
            for x, y in zip(before, after):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                              err_msg=f"tenant {t}")

    @settings(max_examples=10, deadline=None)
    @given(T=st.integers(2, 5), steps=st.integers(1, 6),
           seed=st.integers(0, 99))
    def test_windowed_isolation_and_clocks(self, T, steps, seed):
        """Windowed fleet: tenant ``a``'s traffic (inserts AND the
        rotations its clock triggers) never perturbs tenant ``b``."""
        rng = np.random.default_rng(seed)
        wc = ring.WindowConfig(ace=CFG, num_epochs=3, decay=0.9,
                               rotate_every=2)
        fs = fw.init_fleet_window(wc, T)
        a = int(rng.integers(0, T))
        snap = jax.tree.map(lambda x: np.asarray(x), fs)
        for _ in range(steps):
            buckets = _buckets(rng, 9, CFG.num_bits, CFG.num_tables)
            tids = jnp.full((9,), a, jnp.int32)
            fs = fw.insert_current_fleet(
                fs, tids, buckets, jnp.ones((9,), bool), CFG, gamma=0.9)
            fs = fw.maybe_rotate_fleet(fs, 2, 0.9, tenant_ids=tids)
        assert int(fs.tick[a]) == steps
        for t in range(T):
            if t == a:
                continue
            before = fw.tenant_window_view(
                fw.WindowedFleetState(*(None if x is None else jnp.asarray(x)
                                        for x in snap)), t)
            after = fw.tenant_window_view(fs, t)
            for x, y in zip(before, after):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                              err_msg=f"tenant {t}")

    def test_idle_tenant_parked_on_boundary_never_rerotates(self):
        """Regression: a tenant whose tick sits ON a rotation boundary
        (tick % R == 0) must NOT rotate again on later batches it is
        absent from — the clock predicate is presence-gated, so pure
        neighbour traffic can never cycle an idle tenant's cursor and
        expire its history."""
        wc = ring.WindowConfig(ace=CFG, num_epochs=3, decay=1.0,
                               rotate_every=2)
        fs = fw.init_fleet_window(wc, 2)
        rng = np.random.default_rng(11)
        ones = jnp.ones((9,), bool)
        # tenant 0: exactly R=2 steps -> tick parked on the boundary
        for _ in range(2):
            buckets = _buckets(rng, 9, CFG.num_bits, CFG.num_tables)
            tids = jnp.zeros((9,), jnp.int32)
            fs = fw.insert_current_fleet(fs, tids, buckets, ones, CFG)
            fs = fw.maybe_rotate_fleet(fs, 2, tenant_ids=tids)
        assert int(fs.tick[0]) == 2 and int(fs.cursor[0]) == 1
        snap0 = jax.tree.map(np.asarray, fw.tenant_window_view(fs, 0))
        # tenant-1-only traffic: tenant 0 must stay bitwise frozen
        for _ in range(3):
            buckets = _buckets(rng, 9, CFG.num_bits, CFG.num_tables)
            tids = jnp.ones((9,), jnp.int32)
            fs = fw.insert_current_fleet(fs, tids, buckets, ones, CFG)
            fs = fw.maybe_rotate_fleet(fs, 2, tenant_ids=tids)
        for x, y in zip(snap0, fw.tenant_window_view(fs, 0)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert int(fs.cursor[0]) == 1          # no re-fire
        assert float(jnp.sum(fs.n[0])) > 0     # history intact


class TestValidationGuards:
    def test_flat_offset_overflow_raises(self):
        """T·L·2^K past the int32 offset range must fail loudly at
        config/init time — the routed gather offsets would wrap and
        silently corrupt high tenants."""
        paper = AceConfig(dim=30, num_bits=15, num_tables=50)
        FleetConfig(ace=paper, num_tenants=1310)       # still fits
        with pytest.raises(ValueError, match="int32 offset"):
            FleetConfig(ace=paper, num_tenants=2048)
        with pytest.raises(ValueError, match="int32 offset"):
            fw.init_fleet_window(ring.WindowConfig(
                ace=paper, num_epochs=4, rotate_every=2), 512)

    def test_run_rejects_tenant_ids_for_plain_filter(self):
        """run() with a non-fleet filter must reject tenant_ids instead
        of silently dropping them (and leaking the tenant buffer)."""
        from repro.stream import StreamRunner
        flat = AceDataFilter(d_model=8, num_bits=6, num_tables=8)
        r = StreamRunner(flat, chunk_T=2)
        state, w = r.init()
        batches = [np.zeros((4, 9), np.float32)] * 2
        tids = [np.zeros((4,), np.int32)] * 2
        with pytest.raises(ValueError, match="not a fleet"):
            r.run(state, w, batches, tenant_ids=tids)


class TestFleetStreamRunner:
    def _mk(self, T=4, B=8, CT=6, d=12):
        from repro.stream import StreamRunner
        ff = FleetDataFilter(d_model=d, num_tenants=T, num_bits=6,
                             num_tables=8, warmup_items=8.0, alpha=2.0)
        return ff, StreamRunner(ff, chunk_T=CT), T, B, CT, d

    def test_chunk_equals_sequential_bitwise(self):
        """One fleet scan chunk ≡ CT sequential ``step`` calls, every
        state leaf bitwise; one executable."""
        ff, runner, T, B, CT, d = self._mk()
        rng = np.random.default_rng(6)
        state, w = runner.init()
        feats = jnp.asarray(rng.normal(size=(CT, B, d + 1)), jnp.float32)
        tids = jnp.asarray(rng.integers(0, T, size=(CT, B)), jnp.int32)
        seq, _ = ff.init()
        for i in range(CT):
            seq, _, _ = ff.step(seq, w, feats[i], tids[i])
        out, summary = runner.consume(state, w, feats, tids)
        for got, want in zip(out, seq):
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want))
        # second chunk: same executable
        runner.consume(out, w, feats, tids)
        assert runner.trace_count == 1

    def test_fleet_summary_per_tenant_rows(self):
        """FleetChunkSummary: per-tenant item/kept counts add up, n is
        the per-tenant vector."""
        from repro.stream import FleetChunkSummary, StreamRunner
        T, B, CT, d = 4, 8, 6, 12
        # warmup larger than the whole chunk: every verdict is "keep",
        # so kept == items exactly (per-tenant thresholds stay -inf)
        ff = FleetDataFilter(d_model=d, num_tenants=T, num_bits=6,
                             num_tables=8, warmup_items=1e6, alpha=2.0)
        runner = StreamRunner(ff, chunk_T=CT)
        rng = np.random.default_rng(7)
        state, w = runner.init()
        feats = jnp.asarray(rng.normal(size=(CT, B, d + 1)), jnp.float32)
        tids = jnp.asarray(rng.integers(0, T, size=(CT, B)), jnp.int32)
        state, summary = runner.consume(state, w, feats, tids)
        s = jax.device_get(summary)
        assert isinstance(s, FleetChunkSummary)
        assert s.per_tenant_items.shape == (T,)
        assert s.per_tenant_items.sum() == CT * B
        assert (s.per_tenant_kept <= s.per_tenant_items).all()
        np.testing.assert_array_equal(s.n, np.asarray(state.n))
        # warmup admits everything → kept == items on a cold fleet
        assert s.kept_frac == 1.0

    def test_tenant_ids_contract_validated(self):
        ff, runner, T, B, CT, d = self._mk()
        state, w = runner.init()
        feats = jnp.zeros((CT, B, d + 1), jnp.float32)
        with pytest.raises(AssertionError):
            runner.consume(state, w, feats)            # missing tids
        flat = AceDataFilter(d_model=d, num_bits=6, num_tables=8)
        from repro.stream import StreamRunner
        r2 = StreamRunner(flat, chunk_T=CT)
        s2, w2 = r2.init()
        with pytest.raises(AssertionError):
            r2.consume(s2, w2, feats,
                       jnp.zeros((CT, B), jnp.int32))  # spurious tids

    def test_windowed_fleet_runner_rejected(self):
        from repro.stream import StreamRunner
        ff = FleetDataFilter(d_model=8, num_tenants=2)
        with pytest.raises(NotImplementedError):
            StreamRunner(ff, chunk_T=4, rotate_every=2)


class TestFleetGuardrail:
    def test_tenant_isolation_of_thresholds(self):
        """A traffic regime admitted for tenant a must not move tenant
        b's threshold: b's state stays bitwise frozen while a churns."""
        from repro.serve.engine import Guardrail, GuardrailConfig
        g = Guardrail(GuardrailConfig(d_model=12, num_bits=6,
                                      num_tables=8, warmup_items=8.0,
                                      num_tenants=3))
        rng = np.random.default_rng(8)
        emb = jnp.asarray(rng.normal(size=(8, 3, 12)), jnp.float32)
        g.admit(emb, jnp.asarray([0, 0, 1, 1, 2, 2, 0, 1], jnp.int32))
        b_before = jax.tree.map(np.asarray, tenant_view(g.state, 2))
        for _ in range(4):
            e = jnp.asarray(rng.normal(size=(8, 3, 12)), jnp.float32)
            g.admit(e, jnp.zeros((8,), jnp.int32))     # tenant 0 only
        assert g.trace_count == 1                      # one executable
        b_after = tenant_view(g.state, 2)
        for x, y in zip(b_before, b_after):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_kernel_path_matches_jnp_path(self):
        """use_kernels=True fleet admission ≡ the jnp fleet admission
        (same masks, bitwise states) across several mixed batches."""
        from repro.serve.engine import Guardrail, GuardrailConfig
        gc = GuardrailConfig(d_model=12, num_bits=6, num_tables=8,
                             warmup_items=8.0, num_tenants=3)
        gj, gk = Guardrail(gc), Guardrail(gc, use_kernels=True)
        rng = np.random.default_rng(9)
        for _ in range(3):
            emb = jnp.asarray(rng.normal(size=(8, 3, 12)), jnp.float32)
            tids = jnp.asarray(rng.integers(0, 3, size=(8,)), jnp.int32)
            mj = gj.admit(emb, tids)
            mk = gk.admit(emb, tids)
            np.testing.assert_array_equal(mj, mk)
        np.testing.assert_array_equal(np.asarray(gj.state.counts),
                                      np.asarray(gk.state.counts))

    def test_windowed_fleet_per_tenant_clocks(self):
        """Per-tenant rotation clocks: only tenants that received
        traffic tick; an idle tenant's cursor never moves."""
        from repro.serve.engine import Guardrail, GuardrailConfig
        g = Guardrail(GuardrailConfig(d_model=12, num_bits=6,
                                      num_tables=8, warmup_items=8.0,
                                      num_tenants=3, window_epochs=3,
                                      rotate_every=2))
        rng = np.random.default_rng(10)
        for _ in range(5):
            emb = jnp.asarray(rng.normal(size=(6, 3, 12)), jnp.float32)
            g.admit(emb, jnp.asarray([0, 0, 0, 1, 1, 0], jnp.int32))
        ticks = np.asarray(g.state.tick)
        cursors = np.asarray(g.state.cursor)
        assert ticks[0] == 5 and ticks[1] == 5 and ticks[2] == 0
        assert cursors[2] == 0                       # idle: never rotated
        assert cursors[0] == (5 // 2) % 3            # 2 rotations


# ---------------------------------------------------------------------------
# Sharded parity (fake multi-device CPU; subprocess — slow lane).
# ---------------------------------------------------------------------------

pytest_slow = pytest.mark.slow


def run_py(code: str, devices: int = 2, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest_slow
class TestShardedFleetParity:
    def test_tenant_sharded_bitwise(self):
        """jit/SPMD fleet filter steps on a tenant-sharded placement ≡
        unplaced single-device, bitwise (tenants never couple, so the
        tenant axis is collective-free)."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.fleet import FleetDataFilter
            from repro.dist.sketch_parallel import fleet_shardings_for_layout

            ff = FleetDataFilter(d_model=8, num_tenants=4, num_bits=6,
                                 num_tables=8, warmup_items=8.0)
            mesh = jax.make_mesh((2, 1), ("data", "model"))
            state, w = ff.init()
            rng = np.random.default_rng(0)
            feats = [jnp.asarray(rng.normal(size=(12, 9)), jnp.float32)
                     for _ in range(4)]
            tids = [jnp.asarray(rng.integers(0, 4, size=(12,)), jnp.int32)
                    for _ in range(4)]

            ref = state
            for f, t in zip(feats, tids):
                ref, _, _ = ff.step(ref, w, f, t)

            sh = fleet_shardings_for_layout(ff.ace_cfg, mesh, 4,
                                            "tenant_sharded")
            with jax.set_mesh(mesh):
                st = jax.device_put(state, sh)
                step = jax.jit(ff.step)
                for f, t in zip(feats, tids):
                    st, _, _ = step(st, w, f, t)
            for got, want in zip(jax.tree.leaves(st), jax.tree.leaves(ref)):
                assert bool(jnp.all(jnp.asarray(got) == want)), "leaf differs"
            print("TENANT_SHARDED_OK")
        """)
        assert "TENANT_SHARDED_OK" in out

    def test_tenant_table_composed_bitwise(self):
        """The composed tenant×table 2-D layout on a (2, 2) mesh stays
        bitwise equal to single-device — tenant and L-axis sharding cut
        orthogonal dims of the same (T, L, 2^K) array."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.fleet import FleetDataFilter
            from repro.dist.sketch_parallel import fleet_shardings_for_layout

            ff = FleetDataFilter(d_model=8, num_tenants=4, num_bits=6,
                                 num_tables=8, warmup_items=8.0)
            mesh = jax.make_mesh((2, 2), ("data", "model"))
            state, w = ff.init()
            rng = np.random.default_rng(1)
            feats = [jnp.asarray(rng.normal(size=(12, 9)), jnp.float32)
                     for _ in range(3)]
            tids = [jnp.asarray(rng.integers(0, 4, size=(12,)), jnp.int32)
                    for _ in range(3)]
            ref = state
            for f, t in zip(feats, tids):
                ref, _, _ = ff.step(ref, w, f, t)
            sh = fleet_shardings_for_layout(ff.ace_cfg, mesh, 4,
                                            "tenant_table_sharded")
            with jax.set_mesh(mesh):
                st = jax.device_put(state, sh)
                step = jax.jit(ff.step)
                for f, t in zip(feats, tids):
                    st, _, _ = step(st, w, f, t)
            for got, want in zip(jax.tree.leaves(st), jax.tree.leaves(ref)):
                assert bool(jnp.all(jnp.asarray(got) == want)), "leaf differs"
            print("COMPOSED_OK")
        """, devices=4)
        assert "COMPOSED_OK" in out

    def test_fleet_runner_sharded_bitwise(self):
        """StreamRunner(mesh, tenant_sharded) chunks ≡ unsharded chunks
        bitwise — the same donated scan program in both placements."""
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.fleet import FleetDataFilter
            from repro.stream import StreamRunner

            ff = FleetDataFilter(d_model=8, num_tenants=4, num_bits=6,
                                 num_tables=8, warmup_items=8.0)
            rng = np.random.default_rng(2)
            feats = jnp.asarray(rng.normal(size=(4, 12, 9)), jnp.float32)
            tids = jnp.asarray(rng.integers(0, 4, size=(4, 12)), jnp.int32)

            r0 = StreamRunner(ff, chunk_T=4)
            s0, w = r0.init()
            s0, sum0 = r0.consume(s0, w, feats, tids)

            mesh = jax.make_mesh((2, 1), ("data", "model"))
            with jax.set_mesh(mesh):
                r1 = StreamRunner(ff, chunk_T=4, mesh=mesh,
                                  sketch_layout="tenant_sharded")
                s1, w1 = r1.init()
                s1, sum1 = r1.consume(s1, w1, feats, tids)
            for got, want in zip(jax.tree.leaves(s1), jax.tree.leaves(s0)):
                assert bool(jnp.all(jnp.asarray(got) == jnp.asarray(want)))
            np.testing.assert_array_equal(np.asarray(sum1.per_tenant_kept),
                                          np.asarray(sum0.per_tenant_kept))
            print("RUNNER_SHARDED_OK")
        """)
        assert "RUNNER_SHARDED_OK" in out

    def test_indivisible_tenants_raise(self):
        out = run_py("""
            import jax
            from repro.core.sketch import AceConfig
            from repro.dist.sketch_parallel import fleet_shardings_for_layout
            cfg = AceConfig(dim=4, num_bits=4, num_tables=8, seed=0)
            mesh = jax.make_mesh((2, 1), ("data", "model"))
            try:
                fleet_shardings_for_layout(cfg, mesh, 5, "tenant_sharded")
            except ValueError as e:
                assert "5" in str(e)
                print("RAISED_OK")
        """)
        assert "RAISED_OK" in out
