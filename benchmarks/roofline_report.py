"""Roofline benchmark: reads dryrun_results/*.json, prints the per-cell
three-term table (§Roofline of EXPERIMENTS.md is generated from this)."""
from __future__ import annotations

import os

RESULTS_DIR = os.environ.get("REPRO_DRYRUN_DIR", "dryrun_results")


def run(csv_rows: list[str]) -> None:
    from repro.dist.roofline import build_all, format_table
    if not os.path.isdir(RESULTS_DIR):
        print(f"(no dry-run artifacts in {RESULTS_DIR!r}; run "
              "`python -m repro.launch.dryrun --all --both-meshes` first)")
        return
    rows = build_all(RESULTS_DIR)
    print(format_table(rows))
    for r in rows:
        csv_rows.append(
            f"roofline_{r.arch}_{r.shape}_{r.mesh},0,"
            f"{r.bound_s:.6f}")
        csv_rows.append(
            f"useful_ratio_{r.arch}_{r.shape}_{r.mesh},0,"
            f"{r.useful_ratio:.4f}")
