"""Sliding-window ACE under concept drift: recall recovery + throughput
vs the frozen (cumulative) sketch.

Two measurements, one JSON (``BENCH_window.json``):

1. **Drift scenario.**  ``repro.data.synthetic.make_drift_stream``: one
   inlier cone abruptly replaced by another mid-stream, with a FIXED
   anomaly population injected throughout (so recall is apples-to-apples
   across the shift).  Both detectors run in monitor mode
   (``insert_all=True`` — flag but never gate, so the sketches keep
   seeing the stream) through the SAME ``StreamRunner`` scan machinery:

   * **frozen** — ``AceDataFilter``: counts accumulate forever.  After
     the shift the old regime pins μ and the regime mix inflates the
     Welford σ, so the μ−ασ threshold collapses below every score and
     anomaly recall goes to ~0 — and never comes back (the cumulative
     moments cannot forget).
   * **windowed** — ``repro.window.WindowedAceFilter``: an E-epoch ring
     rotating every R steps.  Once the window slides past the shift
     (E·R steps), μ_w/σ_w describe ONLY the new regime and recall
     recovers.

   Reported: recall/false-flag-rate pre-shift, early post-shift, and
   late post-shift (after the window has fully slid), per detector.

2. **Throughput.**  Scored items/s through the runner for both arms at
   the same shape, interleaved min-of-medians (this container's timings
   swing 2× with scheduler luck; medians of interleaved small timings
   don't), plus host-transfer and retrace counters: windowing must add
   ZERO host syncs (still 1 H2D + 1 D2H per chunk) and ZERO retraces,
   and stay within 10% of the frozen sketch's items/s (the tail-gather
   surcharge — see repro/window/ring.py — is the only per-step cost).

Usage:
    PYTHONPATH=src python -m benchmarks.window_throughput [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import AceDataFilter
from repro.data.synthetic import make_drift_stream
from repro.stream import StreamRunner
from repro.window import WindowedAceFilter


def _detectors(common: dict, num_epochs: int, rotate_every: int):
    return {
        "frozen": AceDataFilter(**common),
        "windowed": WindowedAceFilter(**common, num_epochs=num_epochs,
                                      rotate_every=rotate_every),
    }


def _drift_eval(common, *, num_epochs, rotate_every, steps, shift,
                batch, dim, chunk_T, anomaly_every):
    """Run both detectors over the drift stream; return recall/FPR."""
    stream = make_drift_stream(steps, batch, dim, shift_step=shift,
                               anomaly_every=anomaly_every,
                               anomaly_frac=0.25, seed=0)
    y = np.stack([s[1] for s in stream]).astype(bool)      # (steps, B)
    window_span = num_epochs * rotate_every
    # evaluation bands: pre-shift (armed), early post-shift (window
    # still mixed), late post-shift (window fully past the shift)
    arm = max(3, int(common["warmup_items"] // batch) + 1)
    late0 = min(shift + window_span + rotate_every, steps - chunk_T)
    bands = {"pre": (arm, shift), "post_early": (shift, shift + 30),
             "post_late": (late0, steps)}

    out = {}
    for tag, filt in _detectors(common, num_epochs, rotate_every).items():
        runner = StreamRunner(filt, chunk_T=chunk_T, return_masks=True)
        state, w = runner.init()
        feat = jax.jit(jax.vmap(lambda b: filt.features(b[:, None, :])))
        keeps = []
        for c in range(steps // chunk_T):
            raw = jnp.asarray(np.stack(
                [stream[c * chunk_T + t][0] for t in range(chunk_T)]))
            state, _summary, k = runner.consume(state, w, feat(raw))
            keeps.append(np.asarray(k))
        flags = ~np.concatenate(keeps).astype(bool)
        res = {}
        for band, (lo, hi) in bands.items():
            anom = y[lo:hi]
            res[f"recall_{band}"] = float(flags[lo:hi][anom].mean())
            res[f"fpr_{band}"] = float(flags[lo:hi][~anom].mean())
        res["trace_count"] = runner.trace_count
        out[tag] = res
    out["bands_steps"] = {k: list(v) for k, v in bands.items()}
    out["window_span_steps"] = window_span
    return out


def _bench_throughput(common, *, num_epochs, rotate_every, batch, dim,
                      chunk_T, n_chunks, rounds):
    """Interleaved min-of-medians items/s for both arms + transfer and
    retrace counters."""
    rng = np.random.default_rng(1)
    feats = jnp.asarray(
        rng.normal(size=(chunk_T, batch, dim + 1)) + 1.0, jnp.float32)
    arms = {}
    for tag, filt in _detectors(common, num_epochs, rotate_every).items():
        runner = StreamRunner(filt, chunk_T=chunk_T)
        state, w = runner.init()
        state, summ = runner.consume(state, w, feats)
        jax.device_get(summ)                              # compile + warm
        arms[tag] = [runner, state, w, []]

    d2h = {tag: 0 for tag in arms}
    for _ in range(rounds):
        for tag, arm in arms.items():
            runner, state, w, meds = arm
            ts = []
            for _ in range(n_chunks):
                t0 = time.perf_counter()
                state, summ = runner.consume(state, w, feats)
                jax.device_get(summ)                      # the ONE pull
                d2h[tag] += 1
                ts.append(time.perf_counter() - t0)
            arm[1] = state
            meds.append(float(np.median(ts)))

    out = {}
    for tag, (runner, _state, _w, meds) in arms.items():
        best = min(meds)
        out[tag] = {
            "items_per_s": chunk_T * batch / best,
            "median_chunk_ms": best * 1e3,
            "d2h_per_chunk": d2h[tag] / (rounds * n_chunks),
            "h2d_per_chunk": 1.0,     # the one (reused) stacked feed
            "trace_count": runner.trace_count,
        }
    out["ratio_items_per_s"] = (out["windowed"]["items_per_s"]
                                / out["frozen"]["items_per_s"])
    return out


def run(csv_rows: list[str] | None = None, *,
        json_path: str = "BENCH_window.json", smoke: bool = False) -> dict:
    if smoke and json_path == "BENCH_window.json":
        # don't clobber the committed full-run artifact with smoke shapes
        json_path = "BENCH_window.smoke.json"
    if smoke:
        shape = dict(batch=32, dim=16, chunk_T=10)
        common = dict(d_model=shape["dim"], num_bits=8, num_tables=16,
                      alpha=2.5, warmup_items=64.0, insert_all=True)
        window = dict(num_epochs=3, rotate_every=10)
        drift_kw = dict(steps=60, shift=20, anomaly_every=5)
        thr_kw = dict(n_chunks=4, rounds=2)
    else:
        # dim is production-representative (real embedding features are
        # ≥64-dim): the hash+feature work both arms share then dominates
        # the windowed tail-gather surcharge, which is the regime the
        # ≥0.9× throughput bound speaks to (at toy dims the shared work
        # shrinks and the ratio sits at the bound's edge, 0.88–0.93 on
        # this container's noise)
        shape = dict(batch=512, dim=64, chunk_T=10)
        common = dict(d_model=shape["dim"], num_bits=10, num_tables=32,
                      alpha=2.5, warmup_items=512.0, insert_all=True)
        window = dict(num_epochs=6, rotate_every=20)
        # window spans 120 steps; give the stream room to slide past it
        drift_kw = dict(steps=300, shift=80, anomaly_every=5)
        thr_kw = dict(n_chunks=15, rounds=8)

    drift = _drift_eval(common, **window, **drift_kw,
                        batch=shape["batch"], dim=shape["dim"],
                        chunk_T=shape["chunk_T"])
    thr = _bench_throughput(common, **window, **thr_kw,
                            batch=shape["batch"], dim=shape["dim"],
                            chunk_T=shape["chunk_T"])
    result = {"shape": {**shape, **window,
                        "num_bits": common["num_bits"],
                        "num_tables": common["num_tables"],
                        "alpha": common["alpha"]},
              "drift": drift, "throughput": thr}

    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)

    fz, wd = drift["frozen"], drift["windowed"]
    print(f"drift recall   (shift@{drift_kw['shift']}, window "
          f"{drift['window_span_steps']} steps)")
    print(f"  {'':10s} {'pre':>6s} {'early':>6s} {'late':>6s}   fpr_late")
    for tag, d in (("frozen", fz), ("windowed", wd)):
        print(f"  {tag:10s} {d['recall_pre']:6.2f} "
              f"{d['recall_post_early']:6.2f} {d['recall_post_late']:6.2f}"
              f"   {d['fpr_post_late']:.3f}")
    tf, tw = thr["frozen"], thr["windowed"]
    print(f"throughput     frozen {tf['items_per_s']:10.0f} items/s   "
          f"windowed {tw['items_per_s']:10.0f} items/s   "
          f"ratio {thr['ratio_items_per_s']:.2f}")
    print(f"  transfers: {tw['d2h_per_chunk']:.0f} D2H + "
          f"{tw['h2d_per_chunk']:.0f} H2D per chunk (windowed, rotation "
          f"on) — same as frozen; traces {tw['trace_count']}")

    if csv_rows is not None:
        csv_rows.append(
            f"window_frozen,{1e6 / tf['items_per_s']:.3f},"
            f"{fz['recall_post_late']:.2f}")
        csv_rows.append(
            f"window_windowed,{1e6 / tw['items_per_s']:.3f},"
            f"{wd['recall_post_late']:.2f}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI")
    ap.add_argument("--json", default="BENCH_window.json")
    args = ap.parse_args()
    res = run(json_path=args.json, smoke=args.smoke)

    drift, thr = res["drift"], res["throughput"]
    # structural contracts hold at any scale
    assert thr["windowed"]["trace_count"] == 1, "windowed runner retraced!"
    assert thr["windowed"]["d2h_per_chunk"] <= 1.0, \
        "rotation added host pulls"
    if not args.smoke:
        assert drift["frozen"]["recall_post_late"] <= 0.5, \
            "frozen sketch did not degrade post-shift (scenario broken?)"
        assert drift["windowed"]["recall_post_late"] >= 0.8, \
            "windowed sketch failed to recover recall post-shift"
        assert drift["windowed"]["recall_pre"] >= 0.8, \
            "windowed sketch missed pre-shift anomalies"
        assert thr["ratio_items_per_s"] >= 0.9, \
            f"windowed ingest {thr['ratio_items_per_s']:.2f}x < 0.9x frozen"


if __name__ == "__main__":
    main()
