"""Paper Figure 1b: discriminative power of S(q, D).

Plots (prints) the normalized exact statistic S(q,D)/n as a function of K
for inner points, border points, and outliers of the Fig-1a simulation —
the outlier curve must sit far below the others for K ≳ 5.

Also reports the ACE-estimated score at the paper's K=15, L=50 for the same
three groups, demonstrating the estimator preserves the separation.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import AceConfig, AceEstimator, exact_score
from repro.data.synthetic import make_fig1_dataset


def run(csv_rows: list[str]) -> None:
    pts, inner_idx, border_idx, outliers = make_fig1_dataset()
    data = jnp.asarray(pts)
    groups = {
        "inner": data[inner_idx][:20],
        "border": data[border_idx][:20],
        "outlier": jnp.asarray(outliers),
    }

    print("\n# Fig-1b: normalized exact S(q,D)/n vs K")
    print("K," + ",".join(groups))
    table = {}
    for K in (1, 2, 4, 6, 8, 10, 12, 15):
        row = []
        for name, q in groups.items():
            s = float(jnp.mean(exact_score(q, data, K))) / data.shape[0]
            row.append(s)
            table[(K, name)] = s
        print(f"{K}," + ",".join(f"{v:.6f}" for v in row))

    # separation ratio at the paper's K=15
    sep = table[(15, "outlier")] / max(table[(15, "inner")], 1e-12)
    csv_rows.append(f"fig1_sep_ratio_K15,0,{sep:.6f}")

    # ACE estimator view at K=15, L=50
    cfg = AceConfig(dim=2, num_bits=15, num_tables=50, seed=0)
    est = AceEstimator(cfg).fit(data)
    print("\n# ACE-estimated scores at K=15, L=50 (paper settings)")
    means = {}
    for name, q in groups.items():
        means[name] = float(est.score(q).mean())
        print(f"ace_score_{name},{means[name]:.4f}")
    csv_rows.append(
        "fig1_ace_outlier_vs_inner,0,"
        f"{means['outlier'] / max(means['inner'], 1e-9):.6f}")
