"""ACE throughput microbenchmarks (insert / query / fused-score paths).

Times the jnp reference path and the Pallas kernels (interpret mode on this
CPU container — kernel-body semantics, not TPU speed; TPU timing comes from
the §Roofline model).  Also times the SRHT O(d log d) hash fast path vs the
dense matmul hash at growing d, validating the paper-§2.2 crossover.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AceConfig
from repro.core import sketch as sk
from repro.core.srht import SrhtParams, srht_hash_buckets
from repro.core.srp import hash_buckets


def _time(fn, *args, iters=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters, out


def run(csv_rows: list[str]) -> None:
    B, d = 4096, 36
    cfg = AceConfig(dim=d, num_bits=15, num_tables=50, seed=0)
    w = sk.make_params(cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(B, d)), jnp.float32)
    state = sk.insert(sk.init(cfg), w, x, cfg)

    ins = jax.jit(lambda s_, x_: sk.insert(s_, w, x_, cfg))
    qry = jax.jit(lambda s_, q_: sk.score(s_, w, q_, cfg))
    t_ins, _ = _time(ins, state, x)
    t_qry, _ = _time(qry, state, x)
    print("\n# ACE throughput (XLA-CPU, batch=4096, paper K=15 L=50)")
    print(f"insert: {t_ins * 1e6:.0f} us/batch "
          f"({B / t_ins / 1e6:.2f} M items/s)")
    print(f"query : {t_qry * 1e6:.0f} us/batch "
          f"({B / t_qry / 1e6:.2f} M items/s)")
    csv_rows.append(f"throughput_insert_items_per_s,{t_ins * 1e6:.0f},"
                    f"{B / t_ins:.0f}")
    csv_rows.append(f"throughput_query_items_per_s,{t_qry * 1e6:.0f},"
                    f"{B / t_qry:.0f}")

    # Pallas kernels in interpret mode (semantics check; CPU-speed only)
    from repro.kernels.srp_hash import srp_hash
    from repro.kernels.ace_score_fused import ace_score_fused
    t_h, _ = _time(lambda: srp_hash(x, w, cfg.srp), iters=3)
    t_f, _ = _time(lambda: ace_score_fused(state.counts, x, w, cfg.srp),
                   iters=3)
    print(f"pallas srp_hash (interpret): {t_h * 1e6:.0f} us/batch")
    print(f"pallas fused score (interpret): {t_f * 1e6:.0f} us/batch")
    csv_rows.append(f"throughput_pallas_hash_interp,{t_h * 1e6:.0f},0")

    # SRHT vs dense hashing crossover over dimensionality
    print("\n# hash path: dense matmul vs SRHT (us per 1024-batch)")
    print("d,dense_us,srht_us")
    for dd in (64, 512, 4096):
        c2 = AceConfig(dim=dd, num_bits=15, num_tables=50, seed=1)
        w2 = sk.make_params(c2)
        x2 = jnp.asarray(
            np.random.default_rng(1).normal(size=(1024, dd)), jnp.float32)
        params = SrhtParams(c2.srp)
        td, _ = _time(jax.jit(lambda a: hash_buckets(a, w2, c2.srp)), x2)
        ts, _ = _time(jax.jit(lambda a: srht_hash_buckets(a, params)), x2)
        print(f"{dd},{td * 1e6:.0f},{ts * 1e6:.0f}")
        csv_rows.append(f"throughput_srht_speedup_d{dd},{ts * 1e6:.0f},"
                        f"{td / ts:.2f}")
