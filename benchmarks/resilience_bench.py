"""Degraded-mode serving throughput: healthy vs health-masked admission.

The resilience story (repro.resilience) promises that a guardrail losing
tables to corruption keeps serving from the healthy remainder with the
same hot-path contract — one executable per mode, one host transfer per
batch, no per-call retrace.  This bench puts a number on the price:
``items_per_s`` through ``Guardrail.admit`` on the healthy path vs the
degraded path (⌈L/4⌉ tables masked out of scoring), plus the quarantine
tax of a stream carrying a fixed fraction of non-finite rows.

Both paths are timed over the SAME pre-generated batches with the same
warmed executables; the degraded run flips the serving mask host-side
exactly as ``health_check`` would (a second cached jit executable — the
switch itself costs no syncs, which ``trace_count`` asserts here).

Emits a ``BENCH_resilience.json`` (or ``--json PATH``) so the perf gate
(scripts/bench_gate.py) can hold the degraded-mode throughput floor.

Usage:
    PYTHONPATH=src python -m benchmarks.resilience_bench [--smoke] [--json P]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.engine import Guardrail, GuardrailConfig


def _batches(n_batches: int, batch: int, seq: int, d_model: int,
             nan_frac: float = 0.0, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        e = rng.normal(size=(batch, seq, d_model)).astype(np.float32)
        if nan_frac > 0:
            bad = rng.random(batch) < nan_frac
            e[bad] = np.nan
        out.append(e)
    return out


def _time_admits(g: Guardrail, batches: list[np.ndarray],
                 iters: int) -> float:
    """items/s of the warmed admit program over the batch set."""
    jbs = [jnp.asarray(b) for b in batches]
    g.admit(jbs[0])                                   # warm the executable
    t0 = time.perf_counter()
    for _ in range(iters):
        for jb in jbs:
            g.admit(jb)
    dt = time.perf_counter() - t0
    return iters * len(jbs) * jbs[0].shape[0] / dt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI shapes (small K/L/batch)")
    ap.add_argument("--json", default=None,
                    help="output path (default BENCH_resilience.json)")
    args = ap.parse_args()

    if args.smoke:
        batch, seq, d_model = 32, 2, 16
        num_bits, num_tables = 5, 8
        n_batches, iters = 8, 3
    else:
        batch, seq, d_model = 256, 8, 64
        num_bits, num_tables = 13, 32
        n_batches, iters = 16, 5

    gcfg = GuardrailConfig(d_model=d_model, num_bits=num_bits,
                           num_tables=num_tables, warmup_items=64.0)
    clean = _batches(n_batches, batch, seq, d_model)
    dirty = _batches(n_batches, batch, seq, d_model, nan_frac=0.1, seed=1)
    masked_tables = -(-num_tables // 4)               # ⌈L/4⌉
    mask = np.ones(num_tables, np.float32)
    mask[:masked_tables] = 0.0

    # healthy path
    g = Guardrail(gcfg)
    healthy_ips = _time_admits(g, clean, iters)
    healthy_traces = g.trace_count

    # degraded path: same guardrail, serving mask flipped host-side the
    # way health_check would set it — ONE extra trace, then cached
    g._table_mask = jnp.asarray(mask)
    degraded_ips = _time_admits(g, clean, iters)
    assert g.trace_count == healthy_traces + 1, (
        "degraded executable must be a single extra cached trace, got "
        f"{g.trace_count - healthy_traces}")

    # quarantine tax: healthy mask, 10% non-finite rows in every batch
    g._table_mask = None
    quarantine_ips = _time_admits(g, dirty, iters)
    assert g.trace_count == healthy_traces + 1, \
        "quarantined batches must reuse the healthy executable"
    assert g.quarantined > 0, "dirty stream produced no quarantined rows"

    report = {
        "batch": batch,
        "seq": seq,
        "d_model": d_model,
        "num_bits": num_bits,
        "num_tables": num_tables,
        "masked_tables": masked_tables,
        "n_batches": n_batches,
        "iters": iters,
        "healthy": {"items_per_s": healthy_ips},
        "degraded": {"items_per_s": degraded_ips},
        "quarantine": {"items_per_s": quarantine_ips,
                       "quarantined_rows": int(g.quarantined)},
        "degraded_over_healthy": degraded_ips / healthy_ips,
        "trace_counts": {"total": g.trace_count},
    }
    path = args.json or "BENCH_resilience.json"
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
