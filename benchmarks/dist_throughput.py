"""Distributed ACE throughput: replicated vs table-sharded insert/score.

Runs in a subprocess with fake CPU devices (the benchmark process must keep
seeing 1 device — launch/dryrun.py's contract), builds a 1×N_SHARDS
("data", "model") mesh, and times the shard_map'd repro.dist paths against
the single-device reference at a sketch size where table sharding matters
(K=16, L=64 → 16 MB of int32 counts; bump K to 18+/L to 200+ on real HW).

CPU numbers measure *schedule overhead*, not TPU speed — the point is the
collective structure: replicated insert psums an (L, 2^K) histogram, the
table-sharded one psums only a (B,) float vector.  Emits the standard CSV
rows for benchmarks.run.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

N_SHARDS = 2

_WORKER = """
    import time
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import sketch as sk
    from repro.core.sketch import AceConfig
    from repro.dist.sketch_parallel import (
        make_shardmap_update, make_table_sharded_score,
        make_table_sharded_update, sketch_shardings,
        table_sharded_shardings)

    B, D = {batch}, 24
    cfg = AceConfig(dim=D, num_bits={num_bits}, num_tables={num_tables},
                    seed=0)
    mesh = jax.make_mesh((1, {shards}), ("data", "model"))
    w = sk.make_params(cfg)
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(B, D)), jnp.float32)

    def timeit(fn, *args, iters=8, warmup=2):
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        for _ in range(iters):
            out = jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / iters

    results = {{"memory_bytes": cfg.memory_bytes()}}
    with jax.set_mesh(mesh):
        # replicated layout
        st_rep = jax.device_put(sk.init(cfg), sketch_shardings(mesh))
        upd_rep = jax.jit(make_shardmap_update(mesh, cfg))
        scr_rep = jax.jit(lambda s, q: sk.score(s, w, q, cfg))
        results["replicated_insert_s"] = timeit(upd_rep, st_rep, x, w)
        results["replicated_score_s"] = timeit(scr_rep, st_rep, x)

        # table-sharded layout
        st_ts = jax.device_put(sk.init(cfg), table_sharded_shardings(mesh))
        upd_ts = jax.jit(make_table_sharded_update(mesh, cfg))
        scr_ts = jax.jit(make_table_sharded_score(mesh, cfg))
        results["sharded_insert_s"] = timeit(upd_ts, st_ts, x, w)
        results["sharded_score_s"] = timeit(scr_ts, st_ts, x, w)
    print("DIST_RESULT " + __import__("json").dumps(results))
"""


def run(csv_rows: list[str], batch: int = 2048, num_bits: int = 16,
        num_tables: int = 64) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={N_SHARDS} "
                        + env.get("XLA_FLAGS", ""))
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(repo, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    code = textwrap.dedent(_WORKER).format(
        batch=batch, num_bits=num_bits, num_tables=num_tables,
        shards=N_SHARDS)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    if out.returncode != 0:
        print(f"!! dist_throughput worker failed:\n{out.stderr[-1500:]}",
              file=sys.stderr)
        csv_rows.append("dist_throughput_FAILED,0,0")
        return
    line = next(l for l in out.stdout.splitlines()
                if l.startswith("DIST_RESULT "))
    res = json.loads(line[len("DIST_RESULT "):])

    mb = res["memory_bytes"] / 2**20
    print(f"\n# Distributed ACE throughput (CPU {N_SHARDS}-way tables "
          f"axis, B={batch}, K={num_bits}, L={num_tables} -> "
          f"{mb:.0f} MB counts; {mb / N_SHARDS:.0f} MB/device sharded)")
    for layout in ("replicated", "sharded"):
        for op in ("insert", "score"):
            t = res[f"{layout}_{op}_s"]
            print(f"{layout:10s} {op}: {t * 1e6:8.0f} us/batch "
                  f"({batch / t / 1e6:6.2f} M items/s)")
            csv_rows.append(
                f"dist_{layout}_{op}_items_per_s,{t * 1e6:.0f},"
                f"{batch / t:.0f}")


if __name__ == "__main__":
    rows: list[str] = []
    run(rows)
    print("\n".join(rows))
