"""Paper §3.4 memory accounting: the 4 MB claim, vs dataset size.

ACE state = L·2^K counters (+ projection seeds); everything else about the
data is forgotten.  We print the exact bytes for the paper's settings and
for each benchmark dataset the ratio dataset_bytes / sketch_bytes.
"""
from __future__ import annotations

from repro.core import AceConfig
from repro.core.srp import SrpConfig, projection_memory_bytes, \
    seeds_memory_bytes
from repro.data.synthetic import PAPER_STATS


def run(csv_rows: list[str]) -> None:
    print("\n# Memory accounting (paper §3.4)")
    print("config,counter_bytes,proj_seed_bytes,total_mb")
    for dtype, label in (("int16", "short(paper)"), ("int32", "int32")):
        cfg = AceConfig(dim=36, num_bits=15, num_tables=50,
                        counter_dtype=dtype)
        cb = cfg.memory_bytes()
        sb = seeds_memory_bytes(cfg.srp)
        total = (cb + sb) / 2**20
        print(f"K15_L50_{label},{cb},{sb},{total:.2f}")
        csv_rows.append(f"memory_K15L50_{dtype}_mb,0,{total:.3f}")

    print("\ndataset,n,d,data_mb,sketch_mb,ratio")
    cfg16 = AceConfig(dim=1, num_bits=15, num_tables=50,
                      counter_dtype="int16")
    sk_mb = cfg16.memory_bytes() / 2**20
    for name, (n, _, d) in PAPER_STATS.items():
        data_mb = n * d * 4 / 2**20
        print(f"{name},{n},{d},{data_mb:.1f},{sk_mb:.2f},"
              f"{data_mb / sk_mb:.1f}x")
        csv_rows.append(f"memory_ratio_{name},0,{data_mb / sk_mb:.2f}")
