"""Open-loop (Poisson-arrival) serving benchmark for the front end.

Closed-loop throughput benches (``guardrail_latency`` etc.) answer "how
fast can the device go" — they issue the next batch when the last one
returns, so an overloaded system just slows its own offered rate and
every latency number looks fine.  Production traffic is OPEN loop: the
world offers requests at its own rate, and the only honest questions
are "what latency do served requests see" and "how much is shed" as the
offered load crosses saturation.

This bench measures both, against ``repro.serve.frontend.FrontEnd``:

1. **Device capacity**: closed-loop items/s through the warmed
   ``Guardrail.admit`` at the front end's batch shape (the gated
   throughput metric — ``rep_items_per_s`` feeds the perf gate's
   noise floor).
2. **Front-end capacity**: closed-loop requests/s through the FULL
   ``submit`` + ``pump`` path — per-request Python batching overhead
   included.  THIS is the saturation point the open-loop offered
   rates are scaled from: the front end, not the device, is what the
   Poisson arrivals actually hit, and on small CPU shapes the two can
   differ by orders of magnitude.  (Ungated: it measures the driver
   loop as much as the code.)
3. **Open loop**: seeded Poisson arrivals at 0.5x / 1.0x / 2.0x
   front-end capacity.  Each load point reports served throughput,
   shed rate (queue-full + deadline, per the bounded-queue /
   deadline-aware design), and p50/p99/p999 latency of SERVED
   requests.

The claim under test (asserted here, not just reported): with a
bounded queue and deadline shedding, p999 stays BOUNDED at 2x
saturation — overload converts to measured shed rate instead of
divergent latency.  The structural bound is

    deadline_slack + service_time + max_wait + scheduling_slop

(a served request never waits past its deadline by construction; the
gate asserts against 3x the measured service time for container noise).

Latency leaves are ``*_ms`` (ungated: load-dependent); only
``capacity.items_per_s`` is a gated metric.  Open-loop served rates are
named ``served_items_per_s`` — deliberately OUTSIDE the gate's pattern,
since at sub-saturation loads they echo the offered rate, not the code.

Usage:
    PYTHONPATH=src python -m benchmarks.openloop_bench [--smoke] [--json P]
"""
from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.serve.engine import Guardrail, GuardrailConfig
from repro.serve.frontend import FrontEnd, FrontEndConfig

LOADS = (0.5, 1.0, 2.0)


def _build(smoke: bool):
    if smoke:
        B, S, D = 32, 2, 16
        num_bits, num_tables, T = 5, 8, 4
    else:
        B, S, D = 256, 4, 64
        num_bits, num_tables, T = 13, 32, 8
    policies = tuple("fail_open" if t % 2 == 0 else "fail_closed"
                     for t in range(T))
    g = Guardrail(GuardrailConfig(d_model=D, num_bits=num_bits,
                                  num_tables=num_tables,
                                  warmup_items=64.0, num_tenants=T,
                                  fail_policy=policies))
    # deadline/max_wait stay at the FrontEndConfig defaults (50ms/5ms):
    # at 0.5x load a full batch accumulates within max_wait, so the
    # sub-saturation point runs efficient full batches, while 2x
    # overload is absorbed by the queue bound + deadline shedding
    fcfg = FrontEndConfig(batch_size=B, seq=S, d_model=D,
                          max_queue=4 * B)
    return g, fcfg, T


def _capacity(g, fcfg, T, reps: int, n_batches: int):
    """Closed-loop items/s of the warmed admit program (the gated
    device-throughput metric)."""
    rng = np.random.default_rng(0)
    B, S, D = fcfg.batch_size, fcfg.seq, fcfg.d_model
    embeds = [jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
              for _ in range(n_batches)]
    tenants = jnp.asarray(rng.integers(0, T, size=B), jnp.int32)
    g.admit(embeds[0], tenants)                   # warm the executable
    rep_ips = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for e in embeds:
            np.asarray(g.admit(e, tenants))
        dt = time.perf_counter() - t0
        rep_ips.append(n_batches * B / dt)
    return max(rep_ips), rep_ips


def _frontend_capacity(g, fcfg, T, n_req: int) -> float:
    """Closed-loop requests/s through the full submit+pump path.

    This is the true saturation point of open-loop serving: every
    request pays the per-request Python cost (ticket, shape check,
    batch assembly) on top of its share of a device batch.  Deadlines
    are set far beyond the run length so nothing sheds — the measured
    rate is pure service capacity."""
    rng = np.random.default_rng(7)
    pool = [rng.normal(size=(fcfg.seq, fcfg.d_model)).astype(np.float32)
            for _ in range(64)]
    fe = FrontEnd(g, fcfg)
    t0 = time.perf_counter()
    for k in range(n_req):
        # absolute deadline far beyond the run length — nothing sheds
        fe.submit(pool[k % len(pool)], tenant=k % T,
                  deadline=time.perf_counter() + 60.0)
        if fe.ready():
            fe.pump()
    fe.drain()
    wall = time.perf_counter() - t0
    assert fe.served == n_req, (fe.metrics(), n_req)
    return n_req / wall


def _open_loop(g, fcfg, T, rate: float, n_req: int, seed: int):
    """Offer ``n_req`` requests at Poisson rate ``rate`` (req/s) against
    a fresh FrontEnd; real clock, seeded arrivals.

    Open-loop honesty (wrk2's coordinated-omission rule): every request
    is accountable from its SCHEDULED arrival, not from whenever the
    driver thread got around to submitting it.  Deadlines anchor to the
    scheduled arrival (a request delayed by backlog has already burned
    slack), and reported latency = completion - scheduled arrival."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_req))
    pool = [rng.normal(size=(fcfg.seq, fcfg.d_model)).astype(np.float32)
            for _ in range(64)]
    fe = FrontEnd(g, fcfg)
    tickets = []
    clk = time.perf_counter
    t0 = clk()
    for k in range(n_req):
        while clk() - t0 < arrivals[k]:
            if fe.ready():
                fe.pump()
            else:
                ahead = arrivals[k] - (clk() - t0)
                if ahead > 0.0005:
                    time.sleep(min(ahead, 0.002))
        # absolute deadline anchored at the SCHEDULED arrival: a request
        # delayed by driver backlog has already burned its slack (the
        # coordinated-omission rule — submit lag must not extend the
        # deadline), and one already past it sheds at the next pump
        tickets.append((fe.submit(
            pool[k % len(pool)], tenant=k % T,
            deadline=t0 + arrivals[k] + fcfg.default_deadline),
            arrivals[k]))
        if fe.ready():
            fe.pump()
    t_end = clk()
    while fe.queue_len and clk() - t_end < 1.0:   # bounded tail drain
        fe.pump(force=True)
    wall = clk() - t0
    lat = np.array([tk.t_done - t0 - sched for tk, sched in tickets
                    if tk.status == "served"])
    m = fe.metrics()
    assert m["served"] + m["shed_queue_full"] + m["shed_deadline"] \
        + fe.queue_len == n_req
    pct = (lambda q: float(np.percentile(lat, q) * 1e3)) if len(lat) \
        else (lambda q: float("nan"))
    return {
        "offered_per_s": rate,
        "n_requests": n_req,
        "served_items_per_s": m["served"] / wall,
        "shed_rate": m["shed_rate"],
        "shed_queue_full": m["shed_queue_full"],
        "shed_deadline": m["shed_deadline"],
        "p50_ms": pct(50), "p99_ms": pct(99), "p999_ms": pct(99.9),
        "est_service_ms": m["est_service_s"] * 1e3,
    }


def run(csv_rows: list | None = None, smoke: bool = False,
        json_path: str | None = None) -> dict:
    g, fcfg, T = _build(smoke)
    cap, rep_ips = _capacity(g, fcfg, T, reps=3,
                             n_batches=6 if smoke else 12)
    fe_cap = _frontend_capacity(g, fcfg, T,
                                n_req=1500 if smoke else 6000)
    traces_after_cap = g.trace_count

    points = {}
    for ratio in LOADS:
        rate = ratio * fe_cap
        n_req = int(min(max(400, rate * (1.0 if smoke else 2.0)),
                        40_000 if smoke else 200_000))
        points[f"x{ratio}"] = dict(offered_ratio=ratio,
                                   **_open_loop(g, fcfg, T, rate,
                                                n_req, seed=int(ratio * 10)))
    # mixed-size batches (padded partials) must reuse the SAME admit
    # executable — shape-stable serving is the whole point of padding
    assert g.trace_count == traces_after_cap, (
        f"open-loop serving retraced admit: {g.trace_count} vs "
        f"{traces_after_cap}")

    over = points[f"x{LOADS[-1]}"]
    assert over["shed_rate"] > 0.05, (
        "2x saturation produced no measurable shedding: "
        f"{over['shed_rate']}")
    svc = max(over["est_service_ms"], 0.1)
    bound_ms = fcfg.default_deadline * 1e3 + 3.0 * svc \
        + fcfg.max_wait * 1e3 + 20.0
    assert over["p999_ms"] <= bound_ms, (
        f"p999 {over['p999_ms']:.2f}ms exceeds structural bound "
        f"{bound_ms:.2f}ms at 2x saturation — latency diverged instead "
        "of shedding")

    report = {
        "batch": fcfg.batch_size, "seq": fcfg.seq,
        "d_model": fcfg.d_model, "num_tenants": T,
        "max_queue": fcfg.max_queue,
        "deadline_ms": fcfg.default_deadline * 1e3,
        "max_wait_ms": fcfg.max_wait * 1e3,
        "capacity": {"items_per_s": cap, "rep_items_per_s": rep_ips},
        "frontend_capacity_req_per_s": fe_cap,
        "open_loop": points,
        "p999_bound_ms": bound_ms,
        "trace_counts": {"total": g.trace_count},
    }
    if csv_rows is not None:
        csv_rows.append(
            f"openloop_capacity,{1e6 * fcfg.batch_size / cap:.2f},"
            f"{cap:.0f}")
        csv_rows.append(
            f"openloop_2x_shed,0,{over['shed_rate']:.3f}")
    print(f"  device capacity {cap:.0f} items/s  front-end capacity "
          f"{fe_cap:.0f} req/s")
    for name, pt in points.items():
        print(f"  {name}: offered {pt['offered_per_s']:.0f}/s  served "
              f"{pt['served_items_per_s']:.0f}/s  shed "
              f"{pt['shed_rate']:.1%}  p50 {pt['p50_ms']:.2f}ms  "
              f"p99 {pt['p99_ms']:.2f}ms  p999 {pt['p999_ms']:.2f}ms")
    if json_path is not None:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI shapes (small K/L/batch, short loads)")
    ap.add_argument("--json", default=None,
                    help="output path (default BENCH_openloop[.smoke].json)")
    args = ap.parse_args()
    default = "BENCH_openloop.smoke.json" if args.smoke \
        else "BENCH_openloop.json"
    report = run(smoke=args.smoke, json_path=args.json or default)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
