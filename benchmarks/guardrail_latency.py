"""Guardrail admission latency: pre-PR host-sync path vs device-resident.

Measures per-batch ``admit`` wall time (p50/p99) and the number of XLA
compiles each path triggers while the admitted count varies batch to
batch.  The legacy path (reproduced verbatim below) syncs n/σ to the
host, hashes every batch twice, and retraces on each distinct
admitted-count because of the data-dependent ``kept`` gather; the
device-resident path is one fixed-shape jitted program whose only host
transfer is the returned mask.

Compiles are counted with a ``jax.monitoring`` duration-event hook on
``/jax/core/compile/backend_compile_duration`` (one event per XLA
executable built).

Emits a ``BENCH_guardrail.json`` next to the CWD so the perf trajectory
has machine-readable data points.

Usage:
    PYTHONPATH=src python -m benchmarks.guardrail_latency [--smoke]

``--smoke`` shrinks K/L/batch for CI and additionally drives the fused
Pallas kernel path (``use_kernels=True`` under ``interpret=True``),
asserting it agrees with the reference path.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.monitoring
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.serve.engine import Guardrail, GuardrailConfig

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_compile_count = [0]
_listener_installed = [False]


def _install_compile_counter():
    if _listener_installed[0]:
        return
    def _on_event(name, secs, **kw):  # noqa: ANN001
        if name == _COMPILE_EVENT:
            _compile_count[0] += 1
    jax.monitoring.register_event_duration_secs_listener(_on_event)
    _listener_installed[0] = True


def _admit_legacy(g: Guardrail, embeds: jax.Array) -> np.ndarray:
    """The pre-PR Guardrail.admit, kept here as the benchmark baseline:
    host round-trips (np.asarray(scores), float(n)), a second hash of the
    admitted gather, and a per-admitted-count retrace."""
    feat = g._features(embeds)
    scores = sk.score(g.state, g.w, feat, g.ace_cfg)
    rates = scores / max(float(g.state.n), 1.0)
    mu_rate = sk.mean_rate(g.state)
    sigma = sk.sigma_welford(g.state)
    armed = float(g.state.n) >= g.gcfg.warmup_items
    if armed:
        admit = np.asarray(rates >= mu_rate - g.gcfg.alpha * sigma)
    else:
        admit = np.ones(feat.shape[0], bool)
    kept = jnp.asarray(np.where(admit)[0], jnp.int32)
    if kept.size:
        g.state = sk.insert_buckets(
            g.state, sk.hash_buckets(feat[kept], g.w, g.ace_cfg.srp),
            g.ace_cfg)
    return admit


def _make_batches(n_batches: int, batch: int, seq: int, d_model: int,
                  seed: int = 0) -> list[np.ndarray]:
    """Request-embedding batches with a varying OOD fraction, so the
    admitted count changes batch to batch (the legacy path's retrace
    trigger)."""
    rng = np.random.default_rng(seed)
    base = rng.normal(size=d_model)
    out = []
    for i in range(n_batches):
        e = rng.normal(size=(batch, seq, d_model)).astype(np.float32) * 0.05
        e += base * 2.0
        k = (i * 3) % (batch // 2 + 1)          # 0..B/2 OOD rows, varying
        if k:
            e[:k] = rng.normal(size=(k, seq, d_model)).astype(np.float32) * 4.0
        out.append(e)
    return out


def _drive(admit_fn, batches, warm) -> dict:
    """Warm with ``warm`` batches (compile + arm the sketch), then time
    the rest; returns latency percentiles and the compile count measured
    over the timed region only."""
    for e in batches[:warm]:
        admit_fn(jnp.asarray(e))
    start_compiles = _compile_count[0]
    lat, admitted = [], []
    for e in batches[warm:]:
        x = jnp.asarray(e)
        t0 = time.perf_counter()
        mask = admit_fn(x)                       # np.asarray = the sync
        lat.append((time.perf_counter() - t0) * 1e6)
        admitted.append(int(mask.sum()))
    return {
        "p50_us": float(np.percentile(lat, 50)),
        "p99_us": float(np.percentile(lat, 99)),
        "mean_us": float(np.mean(lat)),
        "compiles_timed_region": _compile_count[0] - start_compiles,
        "admitted_counts": admitted,
    }


def run(csv_rows: list[str] | None = None, *, batch: int = 256,
        n_batches: int = 48, seq: int = 4, d_model: int = 64,
        num_bits: int = 12, num_tables: int = 32,
        json_path: str = "BENCH_guardrail.json",
        smoke: bool = False) -> dict:
    _install_compile_counter()
    if smoke:
        batch, n_batches, seq, d_model = 32, 12, 2, 16
        num_bits, num_tables = 5, 8

    gkw = dict(d_model=d_model, num_bits=num_bits, num_tables=num_tables,
               alpha=3.0, warmup_items=float(batch * 2))
    warm = 4
    batches = _make_batches(n_batches + warm, batch, seq, d_model)

    g_old = Guardrail(GuardrailConfig(**gkw))
    legacy = _drive(lambda e: _admit_legacy(g_old, e), batches, warm)

    g_new = Guardrail(GuardrailConfig(**gkw))
    fused = _drive(g_new.admit, batches, warm)
    fused["trace_count"] = g_new.trace_count

    result = {
        "batch": batch, "seq": seq, "d_model": d_model,
        "num_bits": num_bits, "num_tables": num_tables,
        "n_batches": n_batches,
        "legacy": legacy, "fused": fused,
        "speedup_p50": legacy["p50_us"] / max(fused["p50_us"], 1e-9),
        "speedup_p99": legacy["p99_us"] / max(fused["p99_us"], 1e-9),
    }

    if smoke:
        # Exercise the fused Pallas kernel (interpret=True on CPU) and
        # require mask agreement with the reference device path.  The
        # kernel's tiled f32 hash may flip a sign on a |proj| ~ 0
        # projection (the documented 0.1%-bucket tolerance of the srp
        # kernels), so allow a sliver of disagreement instead of
        # bit-exactness — a real logic bug diverges massively.
        g_js = Guardrail(GuardrailConfig(**gkw))
        g_kn = Guardrail(GuardrailConfig(**gkw), use_kernels=True)
        agree, total = 0, 0
        for e in batches:
            mj, mk = g_js.admit(jnp.asarray(e)), g_kn.admit(jnp.asarray(e))
            agree += int((mj == mk).sum())
            total += mj.size
        assert agree / total > 0.99, f"kernel/jnp mask parity {agree}/{total}"
        assert g_kn.trace_count == 1
        result["kernel_path"] = {"trace_count": g_kn.trace_count,
                                 "mask_agreement": agree / total}

    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)

    print(f"guardrail admit  B={batch} K={num_bits} L={num_tables} "
          f"({n_batches} timed batches)")
    print(f"  legacy : p50 {legacy['p50_us']:9.1f} us   "
          f"p99 {legacy['p99_us']:9.1f} us   "
          f"compiles {legacy['compiles_timed_region']}")
    print(f"  fused  : p50 {fused['p50_us']:9.1f} us   "
          f"p99 {fused['p99_us']:9.1f} us   "
          f"compiles {fused['compiles_timed_region']}   "
          f"traces {fused['trace_count']}")
    print(f"  speedup: p50 {result['speedup_p50']:.2f}x   "
          f"p99 {result['speedup_p99']:.2f}x   -> {json_path}")
    if csv_rows is not None:
        csv_rows.append(
            f"guardrail_admit_legacy,{legacy['p50_us']:.1f},"
            f"{legacy['compiles_timed_region']}")
        csv_rows.append(
            f"guardrail_admit_fused,{fused['p50_us']:.1f},"
            f"{fused['compiles_timed_region']}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny K/L for CI; also drives the Pallas "
                         "kernel path under interpret=True")
    ap.add_argument("--json", default="BENCH_guardrail.json")
    args = ap.parse_args()
    res = run(json_path=args.json, smoke=args.smoke)
    assert res["fused"]["trace_count"] == 1, "fused path retraced!"


if __name__ == "__main__":
    main()
