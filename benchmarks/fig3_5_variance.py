"""Paper Figures 3–5: ACE estimator vs random-sampling estimator (RSE).

For each benchmark dataset: 50 random queries, exact S(q, D) as ground
truth, MSE of each estimator as a function of L (arrays for ACE, samples
for RSE).  The paper's claim: ACE MSE < RSE MSE at every L, on all three
datasets.  MSE here == variance (both estimators are unbiased — Thm 1/2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AceConfig, AceEstimator, exact_score, rse_score
from repro.data.synthetic import make_paper_dataset

K = 15
L_SWEEP = (10, 25, 50, 100)
N_QUERIES = 50


def run(csv_rows: list[str], n_per_dataset: int = 20_000,
        n_seeds: int = 3) -> None:
    for ds_name in ("shuttle", "aloi", "kddcup99_http"):
        ds = make_paper_dataset(ds_name, n=n_per_dataset)
        X = jnp.asarray(ds.x)
        rng = np.random.default_rng(0)
        qidx = rng.choice(ds.n, N_QUERIES, replace=False)
        Q = X[qidx]
        s_true = np.asarray(exact_score(Q, X, K))

        print(f"\n# Fig3-5 analogue [{ds_name}] n={ds.n} d={ds.dim}: "
              "MSE vs L (ACE vs RSE)")
        print("L,mse_ace,mse_rse")
        for L in L_SWEEP:
            ace_err, rse_err = [], []
            for seed in range(n_seeds):
                cfg = AceConfig(dim=ds.dim, num_bits=K, num_tables=L,
                                seed=seed)
                est = AceEstimator(cfg).fit(X)
                ace_err.append(
                    np.mean((np.asarray(est.score(Q)) - s_true) ** 2))
                r = np.asarray(rse_score(Q, X, K, L,
                                         jax.random.PRNGKey(seed)))
                rse_err.append(np.mean((r - s_true) ** 2))
            mse_a, mse_r = float(np.mean(ace_err)), float(np.mean(rse_err))
            print(f"{L},{mse_a:.4f},{mse_r:.4f}")
            csv_rows.append(
                f"fig345_{ds_name}_L{L}_ace_over_rse,0,"
                f"{mse_a / max(mse_r, 1e-12):.6f}")
