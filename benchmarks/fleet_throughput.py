"""Fleet serving throughput: a Python loop of T single-tenant filters vs
ONE fleet program consuming the same mixed-tenant stream.

The scenario the tenant axis exists for: T independent detectors (one per
user/stream) fed by a mixed arrival stream.  Pre-fleet, the only way to
serve it was a host loop — split each arrival batch by tenant, dispatch
each tenant's own jitted single-tenant step, sync its verdict — i.e.
T device programs and T host round-trips per step, with the sketch math
(O(K·L) per item) a rounding error under the dispatch overhead.  The
fleet runs the whole mixed batch through one program (hash once, one
routed gather, per-tenant thresholds, one scatter), and the scan runner
amortises further: T_chunk steps per dispatch, ONE summary pull per
chunk.

Two measurements, one JSON (``BENCH_fleet.json``):

1. **Per-step fleet program** vs the per-tenant Python loop at the same
   arrival shape — the pure batching win.
2. **Chunked fleet runner** (StreamRunner + FleetDataFilter) — batching
   + scan amortisation; transfers and executables counted
   (``trace_count``, D2H per chunk).

Usage:
    PYTHONPATH=src python -m benchmarks.fleet_throughput [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import AceDataFilter
from repro.fleet import FleetDataFilter
from repro.stream import StreamRunner

from benchmarks.guardrail_latency import (_compile_count,
                                          _install_compile_counter)


def _bench(T: int, batch: int, d: int, chunk_T: int, n_chunks: int,
           num_bits: int, num_tables: int):
    """One rep: legacy per-tenant loop, fleet per-step, fleet chunked."""
    assert batch % T == 0, (batch, T)
    per_tenant = batch // T
    n_steps = chunk_T * n_chunks
    kw = dict(num_bits=num_bits, num_tables=num_tables,
              warmup_items=float(per_tenant), alpha=3.0)
    rng = np.random.default_rng(0)

    flt = AceDataFilter(d_model=d, **kw)
    feats_np = []
    tids_np = []
    for _ in range(n_steps):
        feats_np.append(np.asarray(flt.features(jnp.asarray(
            rng.normal(size=(batch, 2, d)) * 0.3 + 1.0, jnp.float32))))
        tids_np.append(np.asarray(
            rng.permutation(np.repeat(np.arange(T), per_tenant))
            .astype(np.int32)))

    # ---- legacy: T single-tenant filters, host-routed.  Per step: split
    # the batch by tenant, dispatch each tenant's jitted step on its own
    # fixed-shape sub-batch, sync each verdict — T programs + T pulls.
    state0, w = flt.init()
    states = [state0] * T

    @jax.jit
    def one_step(state, w, feat):
        return flt.step(state, w, feat)

    # warm (compile once — every tenant shares the executable)
    s_, k_, _ = one_step(states[0], w, jnp.asarray(feats_np[0][:per_tenant]))
    np.asarray(k_)
    start_c = _compile_count[0]
    d2h = 0
    per_step = []
    for feat, tids in zip(feats_np, tids_np):
        t0 = time.perf_counter()
        order = np.argsort(tids, kind="stable")      # host-side routing
        fsorted = feat[order]
        for t in range(T):
            ft = jnp.asarray(fsorted[t * per_tenant:(t + 1) * per_tenant])
            states[t], keep, _ = one_step(states[t], w, ft)
            np.asarray(keep)                         # the verdict sync
            d2h += 1
        per_step.append(time.perf_counter() - t0)
    legacy_med = float(np.median(per_step))
    legacy = {
        "items_per_s": batch / legacy_med,
        "median_step_ms": legacy_med * 1e3,
        "dispatches_per_step": T,
        "d2h_per_step": d2h / n_steps,
        "compiles_timed_region": _compile_count[0] - start_c,
    }

    # ---- fleet, per-step program: one dispatch + one mask pull per step
    ff = FleetDataFilter(d_model=d, num_tenants=T, **kw)
    fstate, fw_ = ff.init()
    fstep = jax.jit(ff.step)
    s_, k_, _ = fstep(fstate, fw_, jnp.asarray(feats_np[0]),
                      jnp.asarray(tids_np[0]))
    np.asarray(k_)
    start_c = _compile_count[0]
    per_step = []
    fstate, _ = ff.init()
    for feat, tids in zip(feats_np, tids_np):
        t0 = time.perf_counter()
        fstate, keep, _ = fstep(fstate, fw_, jnp.asarray(feat),
                                jnp.asarray(tids))
        np.asarray(keep)
        per_step.append(time.perf_counter() - t0)
    step_med = float(np.median(per_step))
    fleet_step = {
        "items_per_s": batch / step_med,
        "median_step_ms": step_med * 1e3,
        "dispatches_per_step": 1,
        "compiles_timed_region": _compile_count[0] - start_c,
    }

    # ---- fleet, chunked runner: 1 H2D + 1 D2H per chunk_T steps
    runner = StreamRunner(ff, chunk_T=chunk_T)
    rstate, rw = runner.init()
    chunks = [(np.stack(feats_np[c * chunk_T:(c + 1) * chunk_T]),
               np.stack(tids_np[c * chunk_T:(c + 1) * chunk_T]))
              for c in range(n_chunks)]
    out = runner.consume(rstate, rw, jnp.asarray(chunks[0][0]),
                         jnp.asarray(chunks[0][1]))
    rstate = out[0]
    jax.device_get(out[1])                            # compile + warm
    start_c = _compile_count[0]
    d2h = h2d = 0
    per_chunk = []
    rstate, rw = runner.init()
    for cf, ct in chunks:
        t0 = time.perf_counter()
        feats = jnp.asarray(cf)
        tids = jnp.asarray(ct)
        h2d += 1
        rstate, summary = runner.consume(rstate, rw, feats, tids)
        jax.device_get(summary)
        d2h += 1                                      # the ONLY pull
        per_chunk.append(time.perf_counter() - t0)
    chunk_med = float(np.median(per_chunk))
    fleet_scan = {
        "items_per_s": chunk_T * batch / chunk_med,
        "median_chunk_ms": chunk_med * 1e3,
        "d2h_per_chunk": d2h / n_chunks,
        "h2d_per_chunk": h2d / n_chunks,
        "trace_count": runner.trace_count,
        "compiles_timed_region": _compile_count[0] - start_c,
    }

    return {
        "num_tenants": T, "batch": batch, "d_model": d,
        "chunk_T": chunk_T, "num_bits": num_bits,
        "num_tables": num_tables, "n_steps": n_steps,
        "legacy_loop": legacy, "fleet_step": fleet_step,
        "fleet_scan": fleet_scan,
        "speedup_step": fleet_step["items_per_s"]
        / max(legacy["items_per_s"], 1e-9),
        "speedup_scan": fleet_scan["items_per_s"]
        / max(legacy["items_per_s"], 1e-9),
    }


def _bench_dtype_sweep(T: int, batch: int, d: int, chunk_T: int,
                       n_chunks: int, num_bits: int, num_tables: int):
    """Quantized count planes on the fleet scan path: float32 vs
    int16 vs int8 at identical shapes and data.

    The fleet table is the dominant HBM resident at production T; the
    effective-bandwidth ratio bills throughput per byte of table
    traffic:

        eff_bw = (items/s_dtype ÷ items/s_float32) × (4 ÷ itemsize)

    A narrow plane that holds throughput (ratio ≈ 1) wins its full
    4/itemsize in bandwidth — same verdicts (exact below saturation),
    half or a quarter of the table bytes moved per scatter/gather.
    """
    assert batch % T == 0
    per_tenant = batch // T
    n_steps = chunk_T * n_chunks
    rng = np.random.default_rng(1)
    flt0 = AceDataFilter(d_model=d, num_bits=num_bits,
                         num_tables=num_tables,
                         warmup_items=float(per_tenant), alpha=3.0)
    feats_np, tids_np = [], []
    for _ in range(n_steps):
        feats_np.append(np.asarray(flt0.features(jnp.asarray(
            rng.normal(size=(batch, 2, d)) * 0.3 + 1.0, jnp.float32))))
        tids_np.append(np.asarray(
            rng.permutation(np.repeat(np.arange(T), per_tenant))
            .astype(np.int32)))
    chunks = [(np.stack(feats_np[c * chunk_T:(c + 1) * chunk_T]),
               np.stack(tids_np[c * chunk_T:(c + 1) * chunk_T]))
              for c in range(n_chunks)]

    sweep = {}
    for dtype in ("float32", "int16", "int8"):
        ff = FleetDataFilter(d_model=d, num_tenants=T, num_bits=num_bits,
                             num_tables=num_tables,
                             warmup_items=float(per_tenant), alpha=3.0,
                             count_dtype=dtype)
        runner = StreamRunner(ff, chunk_T=chunk_T)
        rstate, rw = runner.init()
        out = runner.consume(rstate, rw, jnp.asarray(chunks[0][0]),
                             jnp.asarray(chunks[0][1]))
        jax.device_get(out[1])                        # compile + warm
        per_chunk = []
        rstate, rw = runner.init()
        for cf, ct in chunks:
            t0 = time.perf_counter()
            rstate, summary = runner.consume(rstate, rw,
                                             jnp.asarray(cf),
                                             jnp.asarray(ct))
            jax.device_get(summary)
            per_chunk.append(time.perf_counter() - t0)
        med = float(np.median(per_chunk))
        sweep[dtype] = {
            "items_per_s": chunk_T * batch / med,
            "median_chunk_ms": med * 1e3,
            "itemsize": int(jnp.dtype(dtype).itemsize),
            "table_bytes": int(T * num_tables * (1 << num_bits)
                               * jnp.dtype(dtype).itemsize),
        }

    f32_ips = sweep["float32"]["items_per_s"]
    out = {"dtype_sweep": sweep}
    for dtype in ("int16", "int8"):
        ratio = (sweep[dtype]["items_per_s"] / max(f32_ips, 1e-9)
                 * (4.0 / sweep[dtype]["itemsize"]))
        out[f"eff_bw_ratio_{dtype}"] = ratio
    out["eff_bw_win"] = max(out["eff_bw_ratio_int16"],
                            out["eff_bw_ratio_int8"])
    return out


def run(csv_rows: list[str] | None = None, *,
        json_path: str = "BENCH_fleet.json", smoke: bool = False) -> dict:
    _install_compile_counter()
    if smoke and json_path == "BENCH_fleet.json":
        json_path = "BENCH_fleet.smoke.json"
    if smoke:
        reps = 1
        kw = dict(T=8, batch=16, d=16, chunk_T=8, n_chunks=2,
                  num_bits=8, num_tables=8)
    else:
        reps = 3
        kw = dict(T=64, batch=64, d=32, chunk_T=16, n_chunks=3,
                  num_bits=10, num_tables=16)

    # median-speedup rep (container timing noise; see stream bench)
    runs = [_bench(**kw) for _ in range(reps)]
    runs.sort(key=lambda r: r["speedup_scan"])
    res = runs[len(runs) // 2]
    res["rep_speedups_scan"] = [round(r["speedup_scan"], 2) for r in runs]

    # quantized-plane sweep on the scan path (median of reps for the
    # noisy ratio; the eff_bw win is what the perf gate tracks)
    sweeps = [_bench_dtype_sweep(**kw) for _ in range(reps)]
    sweeps.sort(key=lambda s: s["eff_bw_win"])
    res.update(sweeps[len(sweeps) // 2])
    res["rep_eff_bw_win"] = [round(s["eff_bw_win"], 2) for s in sweeps]

    with open(json_path, "w") as f:
        json.dump(res, f, indent=2)

    lg, fs, fc = res["legacy_loop"], res["fleet_step"], res["fleet_scan"]
    print(f"fleet  T={res['num_tenants']} B={res['batch']} "
          f"d={res['d_model']} K={res['num_bits']} L={res['num_tables']} "
          f"chunk={res['chunk_T']}")
    print(f"  legacy loop : {lg['items_per_s']:10.0f} items/s   "
          f"{lg['dispatches_per_step']} dispatches + "
          f"{lg['d2h_per_step']:.0f} D2H per step")
    print(f"  fleet step  : {fs['items_per_s']:10.0f} items/s   "
          f"1 dispatch per step   ({res['speedup_step']:.1f}x)")
    print(f"  fleet scan  : {fc['items_per_s']:10.0f} items/s   "
          f"{fc['d2h_per_chunk']:.0f} D2H per {res['chunk_T']}-step chunk  "
          f"traces {fc['trace_count']}   ({res['speedup_scan']:.1f}x)")
    for dtype in ("int16", "int8"):
        sw = res["dtype_sweep"][dtype]
        print(f"  {dtype:7s}plane: {sw['items_per_s']:10.0f} items/s   "
              f"table {sw['table_bytes'] >> 10} KB   "
              f"eff-bw {res[f'eff_bw_ratio_{dtype}']:.2f}x")

    if csv_rows is not None:
        csv_rows.append(
            f"fleet_legacy_loop,{1e6 / lg['items_per_s']:.3f},"
            f"{lg['compiles_timed_region']}")
        csv_rows.append(
            f"fleet_scan,{1e6 / fc['items_per_s']:.3f},"
            f"{fc['compiles_timed_region']}")
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI")
    ap.add_argument("--json", default="BENCH_fleet.json")
    args = ap.parse_args()
    res = run(json_path=args.json, smoke=args.smoke)
    assert res["fleet_scan"]["trace_count"] == 1, "fleet runner retraced!"
    assert res["fleet_scan"]["d2h_per_chunk"] <= 1.0, \
        "fleet runner pulled more than once per chunk"
    if not args.smoke:
        assert res["speedup_scan"] >= 10.0, \
            f"fleet scan speedup {res['speedup_scan']:.2f}x < 10x"
        assert res["eff_bw_win"] >= 2.0, \
            f"quantized eff-bw win {res['eff_bw_win']:.2f}x < 2x"


if __name__ == "__main__":
    main()
