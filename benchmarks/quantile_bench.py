"""Quantile-calibrated admission on heavy-tailed multi-tenant traffic:
per-tenant FPR calibration + throughput vs the μ−ασ rule.

The μ−ασ threshold assumes roughly Gaussian per-tenant score
distributions; real traffic is not, and ONE α across tenants
miscalibrates in BOTH directions at once.  The scenario makes that
concrete: one fleet, tenants with the same inlier cone geometry but
different score-distribution shapes —

* **light** — bounded (uniform) angular noise: a tight, thin-tailed
  score distribution.  μ−ασ flags far less than the q budget
  (FPR ≪ q — the under-flag direction: real anomalies must be α σ-units
  out before the detector wakes up).
* **bimodal** — a benign 8% minority sub-population on a rarer cone.
  Its scores sit well below the majority bulk but are perfectly normal
  traffic; μ−ασ walks straight past the mixture's inflated σ and flags
  the ENTIRE minority mode: FPR ≈ 8% ≫ q (the over-flag direction —
  steady false-alarm spam on one tenant's legitimate minority traffic).
* **pareto** — Gaussian noise with an (infinite-variance) Pareto
  multiplier.  The tail inflates σ so the threshold collapses to
  near-zero: the second under-flag direction, AND the burst recall shows
  it misses most true anomalies too.

``threshold_mode="quantile"`` replaces the σ-multiple with the direct
"flag the worst q" rule (repro.quantile): each tenant's threshold is
the q-quantile of its OWN observed rate histogram, so per-tenant FPR ≈ q
by construction, independent of distribution shape — the 2% quantile of
the bimodal tenant lands INSIDE its minority mode's lower tail instead
of wholesale-flagging the mode.  Both modes run the SAME
stream through the SAME ``StreamRunner`` scan machinery in monitor mode
(``insert_all=True``), drifting the inlier cones slowly throughout
(no stationarity gift), then a burst of true scattered-direction
anomalies checks both modes still detect actual outliers.

Reported per mode: per-tenant FPR over the armed segment (quantile mode
must hold every tenant inside [q/2, 2q]; μ−ασ must show FPR < q/2 on
the light tenant AND > 2q on the bimodal one), burst recall, throughput
(items/s, interleaved min-of-medians; quantile ≥ 0.9× μ−ασ) and
``trace_count`` (must be 1 per mode — the histogram scatter rides the
same donated scan, no retraces, no extra host syncs).

Usage:
    PYTHONPATH=src python -m benchmarks.quantile_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.fleet import FleetDataFilter
from repro.stream import StreamRunner

# score-distribution shapes per tenant slot, in order
TENANTS = ("light", "bimodal", "pareto")
BIMODAL_FRAC = 0.08          # benign minority sub-population mass


def _noise(rng, kind: str, rows: int, dim: int, scale: float):
    """Per-tenant angular noise: same scale parameter, different tails."""
    if kind == "light":       # bounded support: zero mass beyond √3·σ
        return rng.uniform(-1.0, 1.0, (rows, dim)) * (scale * np.sqrt(3.0))
    g = rng.normal(size=(rows, dim))
    if kind == "bimodal":     # majority mode: plain Gaussian (the
        return g * scale      # minority mode is injected by the stream)
    # pareto: polynomial tail (index 2.0 — infinite variance)
    mult = rng.pareto(2.0, (rows, 1)) + 0.1
    return g * mult * scale


def _make_stream(steps: int, batch: int, dim: int, T: int, *,
                 burst_from: int, burst_frac: float, drift: float,
                 noise_scale: float, seed: int):
    """Mixed-tenant heavy-tailed drift stream.

    Returns a list of (x (B, dim) f32, tids (B,) i32, y (B,) i8) steps.
    Tenant t's inliers live on a cone that LINEARLY DRIFTS from its home
    direction block toward the next block over the run (``drift`` = total
    fraction of the way moved); anomalies are SCATTERED mixed-sign
    directions (each its own direction — no self-colliding anomaly cone,
    the regime the paper's rare-item score is built for), injected at
    ``burst_frac`` of rows from ``burst_from`` on.
    """
    rng = np.random.default_rng(seed)
    per = batch // T
    blocks = T + 1
    span = dim // blocks
    mus = []
    for t in range(T):
        a = np.zeros(dim)
        a[t * span:(t + 1) * span] = 5.0
        b = np.zeros(dim)
        b[(t + 1) * span:(t + 2) * span] = 5.0
        mus.append((a, b))
    out = []
    for s in range(steps):
        frac = drift * s / max(steps - 1, 1)
        xs, ts, ys = [], [], []
        for t in range(T):
            a, b = mus[t]
            mu = (1.0 - frac) * a + frac * b
            x = np.abs(mu + _noise(rng, TENANTS[t], per, dim, noise_scale))
            if TENANTS[t] == "bimodal":
                # stable benign minority mode: same block, rarer cone
                alt = np.zeros(dim)
                alt[t * span:t * span + span // 2] = 7.0
                rows = rng.uniform(size=per) < BIMODAL_FRAC
                k = int(rows.sum())
                x[rows] = np.abs(alt + rng.normal(size=(k, dim)) * 0.3)
            y = np.zeros(per, np.int8)
            if s >= burst_from and burst_frac > 0:
                k = max(1, int(round(per * burst_frac)))
                rows = rng.choice(per, size=k, replace=False)
                x[rows] = rng.normal(size=(k, dim)) * 3.0
                y[rows] = 1
            xs.append(x)
            ts.append(np.full(per, t, np.int32))
            ys.append(y)
        order = rng.permutation(batch)
        out.append((np.concatenate(xs)[order].astype(np.float32),
                    np.concatenate(ts)[order],
                    np.concatenate(ys)[order]))
    return out


def _filters(common: dict, q: float):
    return {
        "mu_sigma": FleetDataFilter(**common, threshold_mode="mu_sigma"),
        "quantile": FleetDataFilter(**common, threshold_mode="quantile",
                                    quantile_q=q),
    }


def _calibration_eval(common, q, *, steps, batch, dim, T, chunk_T,
                      burst_from, burst_frac, drift, noise_scale,
                      arm_steps):
    """Both modes over the SAME stream; per-tenant FPR + burst recall."""
    stream = _make_stream(steps, batch, dim, T, burst_from=burst_from,
                          burst_frac=burst_frac, drift=drift,
                          noise_scale=noise_scale, seed=0)
    tids_all = np.stack([s[1] for s in stream])            # (steps, B)
    y_all = np.stack([s[2] for s in stream]).astype(bool)

    out = {}
    for tag, filt in _filters(common, q).items():
        runner = StreamRunner(filt, chunk_T=chunk_T, return_masks=True)
        state, w = runner.init()
        feat = jax.jit(jax.vmap(lambda b: filt.features(b[:, None, :])))
        keeps = []
        for c in range(steps // chunk_T):
            raw = jnp.asarray(np.stack(
                [stream[c * chunk_T + t][0] for t in range(chunk_T)]))
            tids = jnp.asarray(tids_all[c * chunk_T:(c + 1) * chunk_T])
            state, _summary, k = runner.consume(state, w, feat(raw), tids)
            keeps.append(np.asarray(k))
        flags = ~np.concatenate(keeps).astype(bool)        # (steps, B)
        res = {"trace_count": runner.trace_count}
        # FPR band: armed, pre-burst, inlier rows only, per tenant
        band = slice(arm_steps, burst_from)
        for t in range(T):
            sel = (tids_all[band] == t) & ~y_all[band]
            res[f"fpr_{TENANTS[t]}"] = float(flags[band][sel].mean())
        anom = y_all[burst_from:]
        res["recall_burst"] = float(flags[burst_from:][anom].mean())
        res["fpr_spread"] = (max(res[f"fpr_{TENANTS[t]}"] for t in range(T))
                             / max(min(res[f"fpr_{TENANTS[t]}"]
                                       for t in range(T)), 1e-6))
        out[tag] = res
    out["q"] = q
    out["band_steps"] = [arm_steps, burst_from]
    return out


def _bench_throughput(common, q, *, batch, dim, T, chunk_T, n_chunks,
                      rounds):
    """Interleaved min-of-medians items/s, both threshold modes."""
    rng = np.random.default_rng(1)
    feats = jnp.asarray(
        rng.normal(size=(chunk_T, batch, dim + 1)) + 1.0, jnp.float32)
    tids = jnp.asarray(rng.integers(0, T, (chunk_T, batch)), jnp.int32)
    arms = {}
    for tag, filt in _filters(common, q).items():
        runner = StreamRunner(filt, chunk_T=chunk_T)
        state, w = runner.init()
        state, summ = runner.consume(state, w, feats, tids)
        jax.device_get(summ)                              # compile + warm
        arms[tag] = [runner, state, w, []]

    for _ in range(rounds):
        for tag, arm in arms.items():
            runner, state, w, meds = arm
            ts = []
            for _ in range(n_chunks):
                t0 = time.perf_counter()
                state, summ = runner.consume(state, w, feats, tids)
                jax.device_get(summ)                      # the ONE pull
                ts.append(time.perf_counter() - t0)
            arm[1] = state
            meds.append(float(np.median(ts)))

    out = {}
    for tag, (runner, _state, _w, meds) in arms.items():
        best = min(meds)
        out[tag] = {
            "items_per_s": chunk_T * batch / best,
            "median_chunk_ms": best * 1e3,
            "d2h_per_chunk": 1.0,
            "trace_count": runner.trace_count,
        }
    out["ratio_items_per_s"] = (out["quantile"]["items_per_s"]
                                / out["mu_sigma"]["items_per_s"])
    return out


def run(csv_rows: list[str] | None = None, *,
        json_path: str = "BENCH_quantile.json", smoke: bool = False) -> dict:
    if smoke and json_path == "BENCH_quantile.json":
        # don't clobber the committed full-run artifact with smoke shapes
        json_path = "BENCH_quantile.smoke.json"
    q = 0.02
    if smoke:
        shape = dict(batch=64, dim=32, chunk_T=8, T=2)
        common = dict(d_model=shape["dim"], num_tenants=shape["T"],
                      num_bits=7, num_tables=8, alpha=2.0,
                      warmup_items=128.0, insert_all=True)
        cal_kw = dict(steps=48, arm_steps=8, burst_from=40,
                      burst_frac=0.3, drift=0.1, noise_scale=0.55)
        thr_kw = dict(n_chunks=3, rounds=2)
    else:
        shape = dict(batch=384, dim=64, chunk_T=10, T=3)
        # α=3: roughly right for Gaussian-ish tails (Φ(−3) ≈ 0.1% ≪ q,
        # the under-flag direction on the bounded tenant) and far too
        # permissive for the heavy multipliers (the over-flag direction)
        common = dict(d_model=shape["dim"], num_tenants=shape["T"],
                      num_bits=10, num_tables=32, alpha=3.0,
                      warmup_items=1024.0, insert_all=True)
        # warmup = 1024 items/tenant = 8 steps @ 128/tenant; measure the
        # FPR band over ~180 drifting steps, then a 20-step burst
        cal_kw = dict(steps=220, arm_steps=20, burst_from=200,
                      burst_frac=0.3, drift=0.1, noise_scale=0.55)
        thr_kw = dict(n_chunks=10, rounds=6)

    cal = _calibration_eval(common, q, **cal_kw, batch=shape["batch"],
                            dim=shape["dim"], T=shape["T"],
                            chunk_T=shape["chunk_T"])
    thr = _bench_throughput(common, q, **thr_kw, batch=shape["batch"],
                            dim=shape["dim"], T=shape["T"],
                            chunk_T=shape["chunk_T"])
    result = {"shape": {**shape, "num_bits": common["num_bits"],
                        "num_tables": common["num_tables"],
                        "alpha": common["alpha"], "q": q},
              "calibration": cal, "throughput": thr}

    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)

    T = shape["T"]
    print(f"per-tenant FPR (target q = {q}, armed pre-burst band)")
    hdr = "".join(f" {TENANTS[t]:>10s}" for t in range(T))
    print(f"  {'':10s}{hdr}   recall_burst")
    for tag in ("mu_sigma", "quantile"):
        d = cal[tag]
        row = "".join(f" {d[f'fpr_{TENANTS[t]}']:10.4f}" for t in range(T))
        print(f"  {tag:10s}{row}   {d['recall_burst']:.2f}")
    tm, tq = thr["mu_sigma"], thr["quantile"]
    print(f"throughput     mu_sigma {tm['items_per_s']:10.0f} items/s   "
          f"quantile {tq['items_per_s']:10.0f} items/s   "
          f"ratio {thr['ratio_items_per_s']:.2f}")
    print(f"  traces: mu_sigma {tm['trace_count']}  "
          f"quantile {tq['trace_count']}")

    if csv_rows is not None:
        for tag, d in (("mu_sigma", tm), ("quantile", tq)):
            csv_rows.append(
                f"quantile_{tag},{1e6 / d['items_per_s']:.3f},"
                f"{cal[tag]['fpr_spread']:.1f}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI")
    ap.add_argument("--json", default="BENCH_quantile.json")
    args = ap.parse_args()
    res = run(json_path=args.json, smoke=args.smoke)

    cal, thr = res["calibration"], res["throughput"]
    q = cal["q"]
    # structural contracts hold at any scale
    for tag in ("mu_sigma", "quantile"):
        assert cal[tag]["trace_count"] == 1, f"{tag} runner retraced!"
        assert thr[tag]["trace_count"] == 1, f"{tag} throughput retraced!"
    if not args.smoke:
        mu, qt = cal["mu_sigma"], cal["quantile"]
        T = res["shape"]["T"]
        # μ−ασ miscalibration, BOTH directions at one α
        assert mu["fpr_light"] < q / 2, \
            f"μ−ασ did not under-flag the light tenant ({mu['fpr_light']})"
        assert mu["fpr_bimodal"] > 2 * q, \
            f"μ−ασ did not over-flag the bimodal tenant " \
            f"({mu['fpr_bimodal']})"
        # quantile mode: every tenant inside the stated band [q/2, 2q]
        for t in range(T):
            f = qt[f"fpr_{TENANTS[t]}"]
            assert q / 2 <= f <= 2 * q, \
                f"quantile FPR out of band for {TENANTS[t]}: {f}"
        assert qt["recall_burst"] >= 0.8, \
            f"quantile mode missed the anomaly burst ({qt['recall_burst']})"
        assert thr["ratio_items_per_s"] >= 0.9, \
            f"quantile ingest {thr['ratio_items_per_s']:.2f}x < 0.9x μ−ασ"


if __name__ == "__main__":
    main()
