"""Heavy-hitter attribution benchmark: ingest overhead + drill-down.

Attribution (``attr_rows > 0``) adds per-chunk work to the ONE jitted
consume program: an energy split, 2·NL·R scatter-adds into the signed
hierarchy, and the fixed-beam findHH descent.  The design claim is that
all of it rides the existing chunk scan — same single executable, same
single summary transfer — at a bounded throughput cost.  This bench
measures that cost and the quality it buys:

1. **Ingest**: items/s through the SAME flat ``StreamRunner`` stream
   with attribution off vs on (interleaved reps, min-of-medians).
   ``attr_off.items_per_s`` / ``attr_on.items_per_s`` are the gated
   metrics; ``overhead_frac`` reports the relative cost (ungated —
   it is a ratio of two gated numbers).
2. **Recovery**: a drifted chunk with planted heavy coordinates; the
   summary's drill-down must name EVERY planted coordinate
   (``recovered_frac`` == 1.0, asserted — a perf number from a broken
   drill-down would gate nothing worth keeping).
3. **Trace discipline**: ``trace_count`` stays 1 per runner — the
   attribution path must not smuggle in a retrace or a second D2H.

Usage:
    PYTHONPATH=src python -m benchmarks.attribution_bench [--smoke] [--json P]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import AceDataFilter
from repro.stream import StreamRunner

PLANTED = (3, 11, 19)
ATTACK_MAG = 9.0


def _build(smoke: bool):
    if smoke:
        return dict(d=32, num_bits=6, num_tables=16, chunk_T=8, B=64,
                    chunks=8, reps=3, attr_rows=5, attr_bits=7)
    return dict(d=64, num_bits=10, num_tables=32, chunk_T=16, B=256,
                chunks=16, reps=5, attr_rows=5, attr_bits=9)


def _filter(p, attr: bool):
    return AceDataFilter(d_model=p["d"], num_bits=p["num_bits"],
                         num_tables=p["num_tables"], warmup_items=64.0,
                         alpha=3.0,
                         attr_rows=p["attr_rows"] if attr else 0,
                         attr_bits=p["attr_bits"])


def _chunks(p, rng):
    d = p["d"]
    feats = rng.normal(size=(p["chunks"], p["chunk_T"], p["B"], d + 1)) \
        .astype(np.float32) * 0.3
    feats[..., : d // 3] += 2.0
    return jnp.asarray(feats)


def _ingest(runner, feats, reps: int):
    """min items/s across reps of the full chunk stream (warmed)."""
    state, w = runner.init()
    state, _ = runner.consume(state, w, feats[0])        # trace once
    items = (feats.shape[0] - 1) * feats.shape[1] * feats.shape[2]
    rep_ips = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for c in range(1, feats.shape[0]):
            state, summary = runner.consume(state, w, feats[c])
        jax.block_until_ready(summary)
        rep_ips.append(items / (time.perf_counter() - t0))
    assert runner.trace_count == 1, runner.trace_count
    return max(rep_ips), rep_ips, state, w


def _recovery(runner, state, w, p, rng):
    """Planted-heavy drill-down through the armed runner."""
    d = p["d"]
    feats = np.array(_chunks(p, rng)[0])
    feats[:, : p["B"] // 4, : d // 3] = 0.1
    for c in PLANTED:
        feats[:, : p["B"] // 4, c] = ATTACK_MAG
    t0 = time.perf_counter()
    state, summary = runner.consume(state, w, jnp.asarray(feats))
    s = jax.device_get(summary)
    dt = time.perf_counter() - t0
    named = {int(c) for c, v in zip(s.hh_coord, s.hh_valid) if v}
    return len(set(PLANTED) & named) / len(PLANTED), dt * 1e3


def run(csv_rows: list | None = None, smoke: bool = False,
        json_path: str | None = None) -> dict:
    p = _build(smoke)
    rng = np.random.default_rng(0)
    feats = _chunks(p, rng)

    r_off = StreamRunner(_filter(p, False), chunk_T=p["chunk_T"],
                         topk=len(PLANTED))
    r_on = StreamRunner(_filter(p, True), chunk_T=p["chunk_T"],
                        topk=len(PLANTED))
    # interleaved reps: container noise hits both arms alike
    ips_off, rep_off, _, _ = _ingest(r_off, feats, p["reps"])
    ips_on, rep_on, state, w = _ingest(r_on, feats, p["reps"])
    recovered, postmortem_ms = _recovery(r_on, state, w, p, rng)
    assert recovered == 1.0, \
        f"drill-down missed planted coords (recovered {recovered:.2f})"

    acfg = _filter(p, True).ace_cfg.attr
    report = {
        "shape": {"d": p["d"], "num_bits": p["num_bits"],
                  "num_tables": p["num_tables"], "chunk_T": p["chunk_T"],
                  "batch": p["B"], "attr_rows": p["attr_rows"],
                  "attr_bits": p["attr_bits"]},
        "attr_bytes": acfg.memory_bytes(),
        "attr_off": {"items_per_s": ips_off,
                     "rep_items_per_s": rep_off},
        "attr_on": {"items_per_s": ips_on,
                    "rep_items_per_s": rep_on},
        "overhead_frac": 1.0 - ips_on / ips_off,
        "recovered_frac": recovered,
        "postmortem_chunk_ms": postmortem_ms,
        "trace_counts": {"off": r_off.trace_count,
                         "on": r_on.trace_count},
    }
    if csv_rows is not None:
        csv_rows.append(f"attrib_ingest_on,"
                        f"{1e6 / max(ips_on, 1e-9):.3f},{ips_on:.0f}")
        csv_rows.append(f"attrib_overhead,0,"
                        f"{report['overhead_frac']:.3f}")
    print(f"  ingest: {ips_off:.0f} items/s off, {ips_on:.0f} on "
          f"({report['overhead_frac']:.1%} overhead, "
          f"+{acfg.memory_bytes() / 1024:.0f} KiB state)")
    print(f"  drill-down named {recovered:.0%} of planted coords; "
          f"post-mortem chunk {postmortem_ms:.2f} ms")
    if json_path is not None:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI shapes (small K/L/batch)")
    ap.add_argument("--json", default=None,
                    help="output path (default BENCH_attrib[.smoke].json)")
    args = ap.parse_args()
    default = "BENCH_attrib.smoke.json" if args.smoke \
        else "BENCH_attrib.json"
    report = run(smoke=args.smoke, json_path=args.json or default)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
