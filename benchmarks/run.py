"""Benchmark driver — one module per paper table/figure (+ roofline report).

Usage:
    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints human-readable sections followed by a machine-readable CSV block
(``name,us_per_call,derived``).  The roofline benchmark is emitted by
``benchmarks.roofline_report`` (reads dry-run artifacts; see launch/dryrun).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller n for CI-speed runs")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale n for ACE (597k rows on KDD)")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark module by name")
    args = ap.parse_args()

    from benchmarks import (attribution_bench, dist_throughput,
                            fig1_discriminative, fig3_5_variance,
                            fleet_throughput, guardrail_latency,
                            memory_table, openloop_bench, quantile_bench,
                            stream_throughput, table3_5_comparison,
                            throughput, window_throughput)
    try:
        from benchmarks import roofline_report
    except ImportError:
        roofline_report = None

    csv_rows: list[str] = []
    ace_n = None if args.full else (4_000 if args.quick else 60_000)
    base_n = 2_000 if args.quick else 12_000
    var_n = 2_000 if args.quick else 20_000

    benches = {
        "fig1": lambda: fig1_discriminative.run(csv_rows),
        "fig3_5": lambda: fig3_5_variance.run(
            csv_rows, n_per_dataset=var_n,
            n_seeds=1 if args.quick else 3),
        "table3_5": lambda: table3_5_comparison.run(
            csv_rows, ace_n=ace_n, baseline_n=base_n),
        "memory": lambda: memory_table.run(csv_rows),
        "throughput": lambda: throughput.run(csv_rows),
        "dist_throughput": lambda: dist_throughput.run(
            csv_rows, batch=512 if args.quick else 2048),
        "guardrail": lambda: guardrail_latency.run(
            csv_rows, smoke=args.quick),
        "stream": lambda: stream_throughput.run(
            csv_rows, smoke=args.quick),
        "window": lambda: window_throughput.run(
            csv_rows, smoke=args.quick),
        "fleet": lambda: fleet_throughput.run(
            csv_rows, smoke=args.quick),
        "openloop": lambda: openloop_bench.run(
            csv_rows, smoke=args.quick),
        "quantile": lambda: quantile_bench.run(
            csv_rows, smoke=args.quick),
        "attrib": lambda: attribution_bench.run(
            csv_rows, smoke=args.quick),
    }
    if roofline_report is not None:
        benches["roofline"] = lambda: roofline_report.run(csv_rows)

    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        print(f"\n{'=' * 66}\n== bench: {name}\n{'=' * 66}")
        try:
            fn()
        except Exception as e:  # keep the suite going; record the failure
            print(f"!! bench {name} failed: {type(e).__name__}: {e}",
                  file=sys.stderr)
            csv_rows.append(f"{name}_FAILED,0,0")
        print(f"[{name}: {time.perf_counter() - t0:.1f}s]")

    print("\n# ===== CSV =====")
    print("name,us_per_call,derived")
    for row in csv_rows:
        print(row)


if __name__ == "__main__":
    main()
