"""Streaming ingest throughput: legacy per-batch filter loop vs the
scan-fused StreamRunner, and dense vs SRHT hashing at the crossover.

Two measurements, one JSON (``BENCH_stream.json``):

1. **Ingest.**  The pre-PR ``AceDataFilter.__call__`` (reproduced verbatim
   below: hashes every batch TWICE, hand-rolls Welford, one device program
   + host syncs per Python-level batch) driven batch-by-batch, against
   ``repro.stream.StreamRunner`` consuming the same stream in chunks of T
   with ONE donated-state scan program and one summary pull per chunk.
   Reports items/s, host transfers (D2H/H2D counted at the drivers' only
   sync points) per batch/chunk, and XLA compile counts over the timed
   region (``jax.monitoring`` duration-event hook).

2. **Hash crossover.**  ``hash_buckets`` under ``hash_mode="dense"`` vs
   ``"srht"`` at d ∈ {64, 4096} (paper K=15, L=50), asserting the
   ``"auto"`` break-even picks the measured winner at BOTH corners —
   dense where the matmul is tiny and SRHT's m-row gather dominates, SRHT
   where O(d·KL) loses to O(d log d + m).

Usage:
    PYTHONPATH=src python -m benchmarks.stream_throughput [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.monitoring
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core.srp import SrpConfig, hash_buckets, make_projections
from repro.core.srht import choose_hash_mode
from repro.data.pipeline import AceDataFilter
from repro.stream import StreamRunner

from benchmarks.guardrail_latency import (_compile_count,
                                          _install_compile_counter)


# ---------------------------------------------------------------------------
# The pre-PR AceDataFilter.__call__, kept verbatim as the ingest baseline:
# TWO hashes per batch (sk.score + sk.hash_buckets), inline Welford.
# ---------------------------------------------------------------------------

def _legacy_filter_call(filt: AceDataFilter, state, w, feat, mask):
    cfg = filt.ace_cfg
    scores = sk.score(state, w, feat, cfg)
    rates = scores / jnp.maximum(state.n, 1.0)
    mu_rate = sk.mean_rate(state)
    sigma = sk.sigma_welford(state)
    armed = state.n >= filt.warmup_items
    anom = jnp.logical_and(armed, rates < mu_rate - filt.alpha * sigma)
    keep = jnp.logical_not(anom)
    buckets = sk.hash_buckets(feat, w, cfg.srp)        # the SECOND hash
    B, L = buckets.shape
    rows = jnp.broadcast_to(
        jnp.arange(L, dtype=jnp.int32)[None, :], (B, L))
    inc = jnp.broadcast_to(keep[:, None], (B, L)).astype(state.counts.dtype)
    new_counts = state.counts.at[rows, buckets].add(inc)
    b = jnp.sum(keep.astype(jnp.float32))
    n = state.n
    tot = n + b
    kept_rates = jnp.where(keep, scores / jnp.maximum(tot, 1.0), 0.0)
    mean_b = jnp.sum(kept_rates) / jnp.maximum(b, 1.0)
    m2_b = jnp.sum(jnp.where(keep, (kept_rates - mean_b) ** 2, 0.0))
    delta = mean_b - state.welford_mean
    safe = jnp.maximum(tot, 1.0)
    new_state = sk.AceState(
        counts=new_counts, n=tot,
        welford_mean=state.welford_mean + delta * b / safe,
        welford_m2=state.welford_m2 + m2_b + delta ** 2 * n * b / safe)
    new_mask = mask * keep[:, None].astype(mask.dtype)
    return new_state, new_mask, jnp.mean(keep.astype(jnp.float32))


def _bench_ingest(n_chunks: int, batch: int, d: int, chunk_T: int,
                  num_bits: int, num_tables: int):
    """Per-batch (legacy) and per-chunk (scan) times, MEDIAN-aggregated —
    this container is a noisy shared CPU, and a single total-wall number
    swings 2× with scheduler luck; medians of many small timings don't.
    The arrival batch is deliberately small (the paper's streaming setting
    is per-item scoring): that is exactly the regime where the legacy
    loop's per-batch dispatch + metric sync dominates the O(K·L) sketch
    work and the scan runner's amortisation pays."""
    n_batches = n_chunks * chunk_T
    filt = AceDataFilter(d_model=d, num_bits=num_bits,
                         num_tables=num_tables, warmup_items=float(batch),
                         alpha=3.0)
    rng = np.random.default_rng(0)
    feats_np = [np.asarray(filt.features(jnp.asarray(
        rng.normal(size=(batch, 2, d)) * 0.3 + 1.0, jnp.float32)))
        for _ in range(n_batches)]
    mask = jnp.ones((batch, 2), jnp.float32)

    # ---- legacy per-batch loop: 1 H2D feed + 1 D2H metric sync per batch
    state, w = filt.init()
    legacy_step = jax.jit(
        lambda s, w, f, m: _legacy_filter_call(filt, s, w, f, m))
    state, _, frac = legacy_step(state, w, jnp.asarray(feats_np[0]), mask)
    float(frac)                                       # compile + warm
    start_c = _compile_count[0]
    h2d = d2h = 0
    per_batch = []
    for f in feats_np:
        t0 = time.perf_counter()
        fd = jnp.asarray(f); h2d += 1                 # the feed
        state, _, frac = legacy_step(state, w, fd, mask)
        _ = float(frac); d2h += 1                     # the metric sync
        per_batch.append(time.perf_counter() - t0)
    legacy_med = float(np.median(per_batch))
    legacy = {
        "items_per_s": batch / legacy_med,
        "median_batch_ms": legacy_med * 1e3,
        "d2h_per_batch": d2h / n_batches,
        "h2d_per_batch": h2d / n_batches,
        "compiles_timed_region": _compile_count[0] - start_c,
        "hashes_per_batch": 2,
    }

    # ---- scan runner: 1 stacked feed + 1 summary pull per T batches
    runner = StreamRunner(filt, chunk_T=chunk_T)
    state, w = runner.init()
    chunks = [np.stack(feats_np[c * chunk_T:(c + 1) * chunk_T])
              for c in range(n_chunks)]
    state, summary = runner.consume(state, w, jnp.asarray(chunks[0]))
    jax.device_get(summary)                           # compile + warm
    start_c = _compile_count[0]
    h2d = d2h = 0
    per_chunk = []
    for c in chunks:
        t0 = time.perf_counter()
        feats = jnp.asarray(c); h2d += 1
        state, summary = runner.consume(state, w, feats)
        jax.device_get(summary); d2h += 1             # the ONLY pull
        per_chunk.append(time.perf_counter() - t0)
    scan_med = float(np.median(per_chunk))
    scan = {
        "items_per_s": chunk_T * batch / scan_med,
        "median_chunk_ms": scan_med * 1e3,
        "d2h_per_chunk": d2h / n_chunks,
        "h2d_per_chunk": h2d / n_chunks,
        "compiles_timed_region": _compile_count[0] - start_c,
        "trace_count": runner.trace_count,
        "hashes_per_batch": 1,
    }
    return {"batch": batch, "d_model": d, "chunk_T": chunk_T,
            "num_bits": num_bits, "num_tables": num_tables,
            "n_batches": n_batches,
            "legacy": legacy, "scan": scan,
            "speedup_items_per_s": scan["items_per_s"]
            / max(legacy["items_per_s"], 1e-9)}


def _bench_hash_crossover(dims, batch: int, iters: int):
    """Wall-time dense vs SRHT ``hash_buckets`` + the auto pick per dim."""
    out = {}
    rng = np.random.default_rng(1)
    for d in dims:
        x = jnp.asarray(rng.normal(size=(batch, d)), jnp.float32)
        res = {}
        for mode in ("dense", "srht"):
            cfg = SrpConfig(dim=d, hash_mode=mode)    # paper K=15, L=50
            w = make_projections(cfg)
            fn = jax.jit(lambda x, w, cfg=cfg: hash_buckets(x, w, cfg))
            jax.block_until_ready(fn(x, w))           # compile
            t0 = time.perf_counter()
            for _ in range(iters):
                r = fn(x, w)
            jax.block_until_ready(r)
            res[mode] = (time.perf_counter() - t0) / iters * 1e6
        auto = choose_hash_mode(SrpConfig(dim=d, hash_mode="auto"))
        winner = "srht" if res["srht"] < res["dense"] else "dense"
        out[str(d)] = {
            "dense_us": res["dense"], "srht_us": res["srht"],
            "auto_picks": auto, "measured_winner": winner,
            "auto_agrees": auto == winner,
        }
    return out


def run(csv_rows: list[str] | None = None, *,
        json_path: str = "BENCH_stream.json", smoke: bool = False) -> dict:
    _install_compile_counter()
    if smoke and json_path == "BENCH_stream.json":
        # don't clobber the committed full-run artifact (cited by the
        # README/ARCHITECTURE tables) with tiny smoke-shape numbers
        json_path = "BENCH_stream.smoke.json"
    if smoke:
        reps = 1
        ingest_kw = dict(n_chunks=3, batch=8, d=32, chunk_T=16,
                         num_bits=8, num_tables=16)
        hash_kw = dict(dims=(64, 4096), batch=64, iters=4)
    else:
        reps = 3
        ingest_kw = dict(n_chunks=4, batch=8, d=64, chunk_T=128,
                         num_bits=10, num_tables=32)
        hash_kw = dict(dims=(64, 4096), batch=256, iters=16)

    # Repeat the whole comparison and report the median-speedup rep: one
    # scheduler hiccup on this shared container can halve either side's
    # throughput for a whole rep, and a single sample would swing the
    # headline 2x in either direction.
    runs = [_bench_ingest(**ingest_kw) for _ in range(reps)]
    runs.sort(key=lambda r: r["speedup_items_per_s"])
    ingest = runs[len(runs) // 2]
    ingest["rep_speedups"] = [round(r["speedup_items_per_s"], 2)
                              for r in runs]
    crossover = _bench_hash_crossover(**hash_kw)
    result = {"ingest": ingest, "hash_crossover": crossover}

    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)

    lg, sc = ingest["legacy"], ingest["scan"]
    print(f"stream ingest  B={ingest['batch']} d={ingest['d_model']} "
          f"K={ingest['num_bits']} L={ingest['num_tables']} "
          f"T={ingest['chunk_T']} ({ingest['n_batches']} batches)")
    print(f"  legacy : {lg['items_per_s']:10.0f} items/s   "
          f"{lg['d2h_per_batch']:.0f} D2H + {lg['h2d_per_batch']:.0f} H2D "
          f"per batch   2 hashes/batch   "
          f"compiles {lg['compiles_timed_region']}")
    print(f"  scan   : {sc['items_per_s']:10.0f} items/s   "
          f"{sc['d2h_per_chunk']:.0f} D2H + {sc['h2d_per_chunk']:.0f} H2D "
          f"per {ingest['chunk_T']}-batch chunk   1 hash/batch   "
          f"compiles {sc['compiles_timed_region']}   "
          f"traces {sc['trace_count']}")
    print(f"  speedup: {ingest['speedup_items_per_s']:.2f}x items/s")
    for d, r in crossover.items():
        print(f"hash d={d:>5}: dense {r['dense_us']:9.1f} us   "
              f"srht {r['srht_us']:9.1f} us   auto->{r['auto_picks']} "
              f"({'agrees' if r['auto_agrees'] else 'DISAGREES'} "
              f"with measurement)")

    if csv_rows is not None:
        csv_rows.append(
            f"stream_ingest_legacy,{1e6 / lg['items_per_s']:.3f},"
            f"{lg['compiles_timed_region']}")
        csv_rows.append(
            f"stream_ingest_scan,{1e6 / sc['items_per_s']:.3f},"
            f"{sc['compiles_timed_region']}")
        for d, r in crossover.items():
            csv_rows.append(f"hash_dense_d{d},{r['dense_us']:.1f},0")
            csv_rows.append(f"hash_srht_d{d},{r['srht_us']:.1f},0")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI")
    ap.add_argument("--json", default="BENCH_stream.json")
    args = ap.parse_args()
    res = run(json_path=args.json, smoke=args.smoke)

    ingest, cross = res["ingest"], res["hash_crossover"]
    assert ingest["scan"]["trace_count"] == 1, "scan runner retraced!"
    assert ingest["scan"]["d2h_per_chunk"] <= 1.0, \
        "scan runner pulled more than once per chunk"
    if not args.smoke:
        assert ingest["speedup_items_per_s"] >= 5.0, \
            f"scan speedup {ingest['speedup_items_per_s']:.2f}x < 5x"
        assert cross["4096"]["srht_us"] < cross["4096"]["dense_us"], \
            "SRHT did not beat dense at d=4096"
        assert all(r["auto_agrees"] for r in cross.values()), \
            f"auto break-even disagrees with measurement: {cross}"


if __name__ == "__main__":
    main()
