"""Paper Tables 3/4/5: the 12-algorithm comparison on the three benchmarks.

For each dataset we report, per algorithm:
  outliers reported / correctly reported / missed / execution seconds /
  speedup of ACE over it — the exact columns of the paper's tables.

Method (paper §5.3): score every point; flag score < μ − σ.

Scale notes (honest accounting on a 1-core CPU container):
* ACE runs at the FULL dataset size (its cost is O(n·d·KL) hashing — this
  is the paper's point).
* The kNN-graph baselines are O(n²·d); at KDD size (597k) that is ~10⁴
  seconds here, so they run on a subsample (default 12k) and we ALSO report
  `extrap_s` = measured · (n_full/n_sub)² — the quadratic-scaling estimate
  at full size (conservative for the paper's ELKI, which uses index
  structures; our speedup claims quote the MEASURED subsample time as the
  baseline denominator, which *understates* ACE's advantage).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.baselines import ALL_BASELINES, run_baseline
from repro.core import AceConfig, AceEstimator
from repro.core import sketch as sk
from repro.data.synthetic import make_paper_dataset

PAPER_K = {"shuttle": 5, "aloi": 5, "kddcup99_http": 10}   # paper Table 2


def _report(scores: np.ndarray, y: np.ndarray):
    mu, sd = scores.mean(), scores.std()
    flagged = scores < (mu - sd)
    reported = int(flagged.sum())
    correct = int((flagged & (y == 1)).sum())
    missed = int(y.sum()) - correct
    return reported, correct, missed


def run(csv_rows: list[str], ace_n: int | None = None,
        baseline_n: int = 12_000, datasets=("shuttle", "aloi",
                                            "kddcup99_http")) -> None:
    for ds_name in datasets:
        ds_full = make_paper_dataset(ds_name, n=ace_n)
        k = PAPER_K[ds_name]

        # ---- ACE at full scale (K=15, L=50 fixed across datasets) -------
        cfg = AceConfig(dim=ds_full.dim, num_bits=15, num_tables=50, seed=0)
        X = jnp.asarray(ds_full.x)
        t0 = time.perf_counter()
        est = AceEstimator(cfg)
        est.update(X)  # one-shot batched insert (streaming-equivalent)
        scores = np.asarray(est.score(X))
        jnp.zeros(()).block_until_ready()
        ace_s = time.perf_counter() - t0
        rep, cor, mis = _report(scores, ds_full.y)
        print(f"\n# Table [{ds_name}] n={ds_full.n} d={ds_full.dim} "
              f"anomalies={int(ds_full.y.sum())} (baselines at "
              f"n={min(baseline_n, ds_full.n)})")
        print("method,reported,correct,missed,seconds,speedup_vs_ace,"
              "extrap_full_s")
        print(f"ace,{rep},{cor},{mis},{ace_s:.3f},1.0,{ace_s:.3f}")
        csv_rows.append(f"table_{ds_name}_ace_recall,0,"
                        f"{cor / max(int(ds_full.y.sum()), 1):.4f}")

        # ---- the 11 baselines on the subsample ---------------------------
        nsub = min(baseline_n, ds_full.n)
        sub = make_paper_dataset(ds_name, n=nsub)
        ysub = sub.y
        scale = (ds_full.n / nsub) ** 2
        graph = inner = None
        # ACE on the same subsample for a like-for-like time ratio
        t0 = time.perf_counter()
        est_s = AceEstimator(AceConfig(dim=sub.dim, num_bits=15,
                                       num_tables=50, seed=0))
        est_s.update(jnp.asarray(sub.x))
        _ = np.asarray(est_s.score(jnp.asarray(sub.x)))
        ace_sub_s = time.perf_counter() - t0

        for name in ALL_BASELINES:
            s, sec, graph, inner = run_baseline(name, sub.x, k=k,
                                                graph=graph, inner=inner)
            rep, cor, mis = _report(s, ysub)
            speed = sec / ace_sub_s
            extrap = sec * (scale if name != "fastvoa"
                            else ds_full.n / nsub)
            print(f"{name},{rep},{cor},{mis},{sec:.3f},{speed:.1f},"
                  f"{extrap:.1f}")
            csv_rows.append(
                f"table_{ds_name}_{name}_speedup,{sec * 1e6:.0f},"
                f"{speed:.2f}")
        csv_rows.append(
            f"table_{ds_name}_ace_subsample_s,{ace_sub_s * 1e6:.0f},1.0")
